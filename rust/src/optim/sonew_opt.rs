//! SONew as a `Direction`: per-tensor diagonal / tridiagonal / banded
//! preconditioning of the flat gradient (Algorithm 1 with the practical
//! EMA statistics; see `crate::sonew` for the kernels).

use std::io::{Read, Write};

use crate::sonew::{BandedState, LambdaMode, TridiagState};
use crate::util::Precision;

use super::{state, Blocks, Direction, HyperParams};

enum State {
    Diag(TridiagState),
    Tridiag(TridiagState),
    Banded(BandedState),
}

pub struct SonewDir {
    state: State,
    mode: LambdaMode,
    eps: f32,
    gamma: f32,
    precision: Precision,
    label: String,
}

fn tensor_ids(n: usize, blocks: &Blocks) -> Vec<f32> {
    let mut ids = vec![0.0f32; n];
    for (i, &(off, len)) in blocks.iter().enumerate() {
        for v in &mut ids[off..off + len] {
            *v = i as f32;
        }
    }
    ids
}

impl SonewDir {
    pub fn diag(n: usize, _blocks: &Blocks, hp: &HyperParams) -> Self {
        Self {
            state: State::Diag(TridiagState::new(n, None).with_storage(hp.precision)),
            mode: LambdaMode::Ema(hp.beta2),
            eps: hp.eps,
            gamma: hp.gamma,
            precision: hp.precision,
            label: "diag-sonew".into(),
        }
    }

    pub fn tridiag(n: usize, blocks: &Blocks, hp: &HyperParams) -> Self {
        let ids = tensor_ids(n, blocks);
        Self {
            state: State::Tridiag(TridiagState::new(n, Some(&ids)).with_storage(hp.precision)),
            mode: LambdaMode::Ema(hp.beta2),
            eps: hp.eps,
            gamma: hp.gamma,
            precision: hp.precision,
            label: "tridiag-sonew".into(),
        }
    }

    pub fn banded(n: usize, blocks: &Blocks, hp: &HyperParams) -> Self {
        let ids = tensor_ids(n, blocks);
        Self {
            state: State::Banded(
                BandedState::new(n, hp.band.max(1), Some(&ids)).with_storage(hp.precision),
            ),
            mode: LambdaMode::Ema(hp.beta2),
            eps: hp.eps,
            gamma: hp.gamma,
            precision: hp.precision,
            label: format!("band-{}-sonew", hp.band.max(1)),
        }
    }

    /// Theory-mode constructor (Thm 3.3 lambda_t schedule) for the regret
    /// experiments.
    pub fn tridiag_sqrt_t(n: usize, g_inf: f32, eps: f32) -> Self {
        Self {
            state: State::Tridiag(TridiagState::new(n, None)),
            mode: LambdaMode::SqrtT { g_inf },
            eps,
            gamma: 0.0,
            precision: Precision::F32,
            label: "tridiag-sonew-sqrt-t".into(),
        }
    }

    /// Edges dropped by Algorithm 3 on the last step (diagnostic).
    pub fn last_dropped(&self) -> usize {
        match &self.state {
            State::Diag(_) => 0,
            State::Tridiag(s) => s.last_dropped,
            State::Banded(s) => s.last_dropped,
        }
    }
}

impl Direction for SonewDir {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn compute(&mut self, g: &[f32], u: &mut [f32]) {
        match &mut self.state {
            State::Diag(s) => s.step_diag(g, u, self.mode, self.eps, self.precision),
            State::Tridiag(s) => {
                s.step(g, u, self.mode, self.eps, self.gamma, self.precision)
            }
            State::Banded(s) => {
                s.step(g, u, self.mode, self.eps, self.gamma, self.precision)
            }
        }
    }

    fn memory_floats(&self) -> usize {
        match &self.state {
            // diag-SONew stores only hd
            State::Diag(s) => s.len(),
            State::Tridiag(s) => s.memory_floats(),
            State::Banded(s) => s.memory_floats(),
        }
    }

    fn memory_bytes(&self) -> usize {
        match &self.state {
            // diag-SONew stores only hd
            State::Diag(s) => s.hd.bytes(),
            State::Tridiag(s) => s.memory_bytes(),
            State::Banded(s) => s.memory_bytes(),
        }
    }

    /// Statistics (`hd`/`ho` or the stacked band diagonals) plus the
    /// step clock; edge masks are structural and rebuilt from the spec.
    fn save_state(&self, w: &mut dyn Write) -> std::io::Result<()> {
        state::write_tag(w, b"SONW")?;
        match &self.state {
            State::Diag(s) | State::Tridiag(s) => {
                state::write_u64(w, s.step_count())?;
                state::write_state_vec(w, &s.hd)?;
                state::write_state_vec(w, &s.ho)?;
            }
            State::Banded(s) => {
                state::write_u64(w, s.step_count())?;
                state::write_u64(w, s.diags.len() as u64)?;
                for d in &s.diags {
                    state::write_state_vec(w, d)?;
                }
            }
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut dyn Read) -> std::io::Result<()> {
        state::expect_tag(r, b"SONW", &self.label)?;
        match &mut self.state {
            State::Diag(s) | State::Tridiag(s) => {
                let t = state::read_u64(r)?;
                s.set_step_count(t);
                state::read_state_vec_into(r, &mut s.hd, "sonew.hd")?;
                state::read_state_vec_into(r, &mut s.ho, "sonew.ho")?;
            }
            State::Banded(s) => {
                let t = state::read_u64(r)?;
                s.set_step_count(t);
                let nd = state::read_u64(r)? as usize;
                if nd != s.diags.len() {
                    return Err(state::bad_state(format!(
                        "{}: {nd} diagonals in state vs band+1 = {}",
                        self.label,
                        s.diags.len()
                    )));
                }
                for d in &mut s.diags {
                    state::read_state_vec_into(r, d, "sonew.diags")?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_matches_table1() {
        let hp = HyperParams { band: 4, ..Default::default() };
        let blocks = vec![(0usize, 1000usize)];
        assert_eq!(SonewDir::diag(1000, &blocks, &hp).memory_floats(), 1000);
        assert_eq!(SonewDir::tridiag(1000, &blocks, &hp).memory_floats(), 2000);
        assert_eq!(SonewDir::banded(1000, &blocks, &hp).memory_floats(), 5000);
    }

    /// Measure preconditioner quality directly: install H = P_G(Sigma)
    /// exactly (LambdaMode::Ema(1.0) leaves statistics untouched) and
    /// compare the preconditioned direction X g against the true Newton
    /// direction Sigma^{-1} g, averaged over random probes. Wider sparsity
    /// patterns solve (11) over a superset, so alignment improves — the
    /// paper's core qualitative claim. (Deterministic rank-1 gradient
    /// streams are the Lemma A.13 degenerate case, tested separately.)
    fn newton_cosine(band: usize, sigma_band: usize, n: usize, seed: u64) -> f32 {
        use crate::linalg::{spd_solve, Mat};
        use crate::sonew::{BandedState, LambdaMode, TridiagState};
        use crate::util::Precision;
        let mut sigma = Mat::zeros(n, n);
        for i in 0..n {
            *sigma.at_mut(i, i) = 2.0;
            for k in 1..=sigma_band {
                if i + k < n {
                    *sigma.at_mut(i, i + k) = 0.8 / k as f32;
                    *sigma.at_mut(i + k, i) = 0.8 / k as f32;
                }
            }
        }
        let mut rng = crate::util::Rng::new(seed);
        let mut acc = 0.0f32;
        let probes = 40;
        for _ in 0..probes {
            let g = rng.normal_vec(n);
            let newton = spd_solve(&sigma, &g).unwrap();
            let mut u = vec![0.0f32; n];
            if band == 0 {
                let mut st = TridiagState::new(n, None);
                for j in 0..n {
                    st.hd.set(j, sigma.at(j, j));
                }
                st.step_diag(&g, &mut u, LambdaMode::Ema(1.0), 0.0, Precision::F32);
            } else {
                let mut st = BandedState::new(n, band, None);
                for k in 0..=band {
                    for j in 0..n {
                        if j + k < n {
                            st.diags[k].set(j, sigma.at(j + k, j));
                        }
                    }
                }
                st.step(&g, &mut u, LambdaMode::Ema(1.0), 0.0, 0.0, Precision::F32);
            }
            acc += crate::linalg::dot(&u, &newton)
                / (crate::linalg::norm2(&u) * crate::linalg::norm2(&newton));
        }
        acc / probes as f32
    }

    #[test]
    fn tridiag_closer_to_newton_than_diag() {
        let n = 40;
        let c_diag = newton_cosine(0, 4, n, 7);
        let c_tri = newton_cosine(1, 4, n, 7);
        assert!(
            c_tri > c_diag + 0.01,
            "tridiag cos {c_tri} should beat diag cos {c_diag}"
        );
        assert!(c_tri > 0.95, "{c_tri}");
    }

    #[test]
    fn band_size_ordering_toward_newton() {
        // Table 3's expectation: wider bands capture more correlation.
        let n = 40;
        let c1 = newton_cosine(1, 4, n, 9);
        let c4 = newton_cosine(4, 4, n, 9);
        assert!(
            c4 > c1 - 1e-4,
            "band-4 cos {c4} should not lose to band-1 cos {c1}"
        );
    }

    #[test]
    fn last_dropped_surfaces_algorithm3() {
        let n = 16;
        let hp = HyperParams { gamma: 1e-2, eps: 0.0, beta2: 0.5, ..Default::default() };
        let mut d = SonewDir::tridiag(n, &vec![(0, n)], &hp);
        let g = vec![1.0f32; n]; // perfectly correlated adjacent entries
        let mut u = vec![0.0f32; n];
        d.compute(&g, &mut u);
        assert!(d.last_dropped() > 0);
        assert!(u.iter().all(|v| v.is_finite()));
    }
}
