//! Cross-layer integration tests: the L3-native implementations against
//! the L1/L2 AOT artifacts executed through PJRT. These are the tests
//! that prove the three layers compose; they skip gracefully when
//! `make artifacts` has not been run.

use sonew::optim::{build, HyperParams, OptKind};
use sonew::runtime::{Engine, HostTensor};
use sonew::sonew::{LambdaMode, TridiagState};
use sonew::util::prop::max_rel_err;
use sonew::util::{Precision, Rng};

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !Engine::available(&dir) {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Engine::open(dir).expect("open artifacts"))
}

/// The Pallas tridiag kernel inside the HLO artifact must agree with the
/// native Rust kernel over a multi-step (H, g) stream — the SONew hot
/// path exists twice by design (DESIGN.md §6) and must be bit-comparable.
#[test]
fn sonew_hlo_pallas_matches_native() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("sonew_tridiag_ae_small").unwrap().clone();
    let n = spec.inputs[0].elements();
    let beta2 = spec.meta_f64("beta2").unwrap() as f32;
    let eps = spec.meta_f64("eps").unwrap() as f32;
    let gamma = spec.meta_f64("gamma").unwrap_or(0.0) as f32;
    let tids = engine.manifest.layout("ae_small").unwrap().tensor_ids();

    let mut native = TridiagState::new(n, Some(&tids));
    let mut hd = vec![0.0f32; n];
    let mut ho = vec![0.0f32; n];
    let mut u_native = vec![0.0f32; n];
    let mut rng = Rng::new(11);

    for step in 0..4 {
        let g = rng.normal_vec(n);
        let out = engine
            .exec(
                "sonew_tridiag_ae_small",
                &[
                    HostTensor::F32(hd.clone()),
                    HostTensor::F32(ho.clone()),
                    HostTensor::F32(g.clone()),
                    HostTensor::F32(tids.clone()),
                ],
            )
            .unwrap();
        let hd2 = out[0].as_f32().unwrap();
        let ho2 = out[1].as_f32().unwrap();
        let u_hlo = out[2].as_f32().unwrap();

        native.step(&g, &mut u_native, LambdaMode::Ema(beta2), eps, gamma, Precision::F32);

        assert!(
            max_rel_err(hd2, &native.hd) < 1e-5,
            "step {step}: hd diverged ({})",
            max_rel_err(hd2, &native.hd)
        );
        assert!(
            max_rel_err(ho2, &native.ho) < 1e-5,
            "step {step}: ho diverged ({})",
            max_rel_err(ho2, &native.ho)
        );
        // Early-step statistics are near-degenerate (rank ~ t), so the
        // 1/schur amplification magnifies fp32 ordering differences on a
        // few lanes; require tight global alignment + bounded worst lane.
        let cos = sonew::linalg::dot(u_hlo, &u_native)
            / (sonew::linalg::norm2(u_hlo) * sonew::linalg::norm2(&u_native));
        assert!(cos > 0.9999, "step {step}: direction cos {cos}");
        assert!(
            max_rel_err(u_hlo, &u_native) < 5e-2,
            "step {step}: direction diverged ({})",
            max_rel_err(u_hlo, &u_native)
        );
        hd = hd2.to_vec();
        ho = ho2.to_vec();
    }
}

/// The HLO grads program and the native Rust MLP compute the same model:
/// identical parameters + identical batch => matching loss and gradients.
#[test]
fn hlo_grads_match_native_mlp() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("ae_small_grads_b64").unwrap().clone();
    let n = spec.inputs[0].elements();
    let batch_elems = spec.inputs[1].elements();
    let pixels = spec.inputs[1].dims[1];
    let batch = batch_elems / pixels;

    let mlp = sonew::models::Mlp::autoencoder_small();
    assert_eq!(mlp.total, n, "layout mismatch between python and rust");
    let mut rng = Rng::new(5);
    let params = mlp.init(&mut rng);
    let x_flat = rng.uniform_vec(batch_elems, 0.0, 1.0);

    let (loss_hlo, grads_hlo) = engine
        .loss_and_grad("ae_small_grads_b64", &params, vec![HostTensor::F32(x_flat.clone())])
        .unwrap();
    let x = sonew::linalg::Mat::from_rows(batch, pixels, x_flat);
    let (loss_native, grads_native) = mlp.loss_and_grad(&params, &x);

    assert!(
        (loss_hlo - loss_native).abs() < 1e-2 * loss_native.abs().max(1.0),
        "loss: hlo {loss_hlo} vs native {loss_native}"
    );
    assert!(
        max_rel_err(&grads_hlo, &grads_native) < 1e-3,
        "grads diverged: {}",
        max_rel_err(&grads_hlo, &grads_native)
    );
}

/// End-to-end smoke on the deployment path: HLO grads + HLO Pallas SONew
/// update + rust coordinator reduce the AE loss.
#[test]
fn hlo_end_to_end_training_reduces_loss() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("ae_small_grads_b64").unwrap().clone();
    let n = spec.inputs[0].elements();
    let pixels = spec.inputs[1].dims[1];
    let batch = spec.inputs[1].elements() / pixels;
    let tids = engine.manifest.layout("ae_small").unwrap().tensor_ids();

    let mlp = sonew::models::Mlp::autoencoder_small();
    let mut rng = Rng::new(7);
    let mut params = mlp.init(&mut rng);
    let mut images = sonew::data::SynthImages::new(3);

    let mut hd = vec![0.0f32; n];
    let mut ho = vec![0.0f32; n];
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..12 {
        // 28x28 synth images pooled to the small AE's 14x14 input
        let (img, _) = images.batch(batch);
        let mut x = Vec::with_capacity(batch * pixels);
        for r in 0..batch {
            let row = img.row(r);
            for oy in 0..14 {
                for ox in 0..14 {
                    let mut acc = 0.0;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            acc += row[(oy * 2 + dy) * 28 + ox * 2 + dx];
                        }
                    }
                    x.push(acc / 4.0);
                }
            }
        }
        let (loss, grads) = engine
            .loss_and_grad("ae_small_grads_b64", &params, vec![HostTensor::F32(x)])
            .unwrap();
        let out = engine
            .exec(
                "sonew_tridiag_ae_small",
                &[
                    HostTensor::F32(std::mem::take(&mut hd)),
                    HostTensor::F32(std::mem::take(&mut ho)),
                    HostTensor::F32(grads),
                    HostTensor::F32(tids.clone()),
                ],
            )
            .unwrap();
        let mut it = out.into_iter();
        hd = it.next().unwrap().into_f32().unwrap();
        ho = it.next().unwrap().into_f32().unwrap();
        let mut u = it.next().unwrap().into_f32().unwrap();
        // gradient-norm grafting (§5): early rank-deficient statistics
        // make the raw Newton direction enormous; the paper always runs
        // SONew with a grafted step magnitude.
        let gn = {
            // recompute ||g|| from the statistics innovation is overkill;
            // normalize u to unit norm and use a fixed trust region.
            let un = sonew::linalg::norm2(&u);
            if un > 1e-30 { 1.0 / un } else { 0.0 }
        };
        for (p, &ui) in params.iter_mut().zip(&u) {
            *p -= 0.05 * ui * gn;
        }
        u.clear();
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
        assert!(loss.is_finite());
    }
    let first = first.unwrap();
    assert!(last < first, "no progress: {first} -> {last}");
}

/// Banded artifact parity on the small AE.
#[test]
fn sonew_banded_hlo_matches_native() {
    let Some(engine) = engine() else { return };
    let Ok(spec) = engine.spec("sonew_band4_ae_small") else { return };
    let spec = spec.clone();
    let n = spec.inputs[1].elements();
    let b = spec.inputs[0].dims[0] - 1;
    let beta2 = spec.meta_f64("beta2").unwrap() as f32;
    let eps = spec.meta_f64("eps").unwrap() as f32;
    let tids = engine.manifest.layout("ae_small").unwrap().tensor_ids();

    let mut native = sonew::sonew::BandedState::new(n, b, Some(&tids));
    let mut diags = vec![0.0f32; (b + 1) * n];
    let mut u_native = vec![0.0f32; n];
    let mut rng = Rng::new(13);
    for step in 0..2 {
        let g = rng.normal_vec(n);
        let out = engine
            .exec(
                "sonew_band4_ae_small",
                &[
                    HostTensor::F32(diags.clone()),
                    HostTensor::F32(g.clone()),
                    HostTensor::F32(tids.clone()),
                ],
            )
            .unwrap();
        let d2 = out[0].as_f32().unwrap();
        let u_hlo = out[1].as_f32().unwrap();
        native.step(&g, &mut u_native, LambdaMode::Ema(beta2), eps, 0.0, Precision::F32);
        let native_flat: Vec<f32> = native.diags.concat();
        assert!(
            max_rel_err(d2, &native_flat) < 1e-4,
            "step {step}: banded stats diverged ({})",
            max_rel_err(d2, &native_flat)
        );
        assert!(
            max_rel_err(u_hlo, &u_native) < 5e-3,
            "step {step}: banded direction diverged ({})",
            max_rel_err(u_hlo, &u_native)
        );
        diags = d2.to_vec();
    }
}

/// Failure injection: wrong shapes and unknown artifacts produce clean
/// errors, not aborts.
#[test]
fn engine_rejects_bad_inputs() {
    let Some(engine) = engine() else { return };
    assert!(engine.exec("no_such_artifact", &[]).is_err());
    let err = engine
        .exec("sonew_tridiag_ae_small", &[HostTensor::F32(vec![1.0])])
        .unwrap_err();
    assert!(format!("{err}").contains("inputs"), "{err}");
    let spec = engine.spec("sonew_tridiag_ae_small").unwrap().clone();
    let n = spec.inputs[0].elements();
    let err = engine
        .exec(
            "sonew_tridiag_ae_small",
            &[
                HostTensor::F32(vec![0.0; n]),
                HostTensor::F32(vec![0.0; n]),
                HostTensor::F32(vec![0.0; 3]), // wrong length
                HostTensor::F32(vec![0.0; n]),
            ],
        )
        .unwrap_err();
    assert!(format!("{err}").contains("elements"), "{err}");
}

/// Grafted tridiag-SONew through the full optimizer stack trains the
/// (native) small AE — the Table 2 pipeline end to end without artifacts.
#[test]
fn full_optimizer_stack_trains_small_ae() {
    let mlp = sonew::models::Mlp::autoencoder_small();
    let mut rng = Rng::new(2);
    let mut params = mlp.init(&mut rng);
    let hp = HyperParams { gamma: 1e-8, ..Default::default() };
    let mut opt = build(OptKind::TridiagSonew, mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp);
    let mut images = sonew::data::SynthImages::new(9);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..25 {
        let (x, _) = images.batch(32);
        // pool to 14x14
        let mut data = Vec::with_capacity(32 * 196);
        for r in 0..32 {
            let row = x.row(r);
            for oy in 0..14 {
                for ox in 0..14 {
                    let mut acc = 0.0;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            acc += row[(oy * 2 + dy) * 28 + ox * 2 + dx];
                        }
                    }
                    data.push(acc / 4.0);
                }
            }
        }
        let xm = sonew::linalg::Mat::from_rows(32, 196, data);
        let (loss, g) = mlp.loss_and_grad(&params, &xm);
        opt.step(&mut params, &g, 5e-3);
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    assert!(last < 0.95 * first.unwrap(), "{:?} -> {last}", first);
}
