//! Cross-layer integration tests, all driven through the runtime
//! `Backend` trait object.
//!
//! The native-backend tests always run: a clean clone with no Python, no
//! artifacts and no PJRT toolchain still trains end to end. The PJRT
//! parity tests (L3-native implementations against the L1/L2 AOT
//! artifacts) additionally require the `xla` cargo feature and a
//! compiled `artifacts/` directory; they skip gracefully otherwise.

use sonew::coordinator::trainer::BackendAeProvider;
use sonew::coordinator::{train_single, Schedule, TrainConfig};
use sonew::optim::{HyperParams, OptSpec};
use sonew::runtime::{Backend, HostTensor, NativeBackend};
use sonew::util::Rng;

#[cfg(feature = "xla")]
use sonew::sonew::{LambdaMode, TridiagState};
#[cfg(feature = "xla")]
use sonew::util::prop::max_rel_err;
#[cfg(feature = "xla")]
use sonew::util::Precision;

/// 28x28 synth images average-pooled to the small AE's 14x14 input.
fn pooled_small_batch(images: &mut sonew::data::SynthImages, batch: usize) -> Vec<f32> {
    let (img, _) = images.batch(batch);
    let mut x = Vec::with_capacity(batch * 196);
    for r in 0..batch {
        let row = img.row(r);
        for oy in 0..14 {
            for ox in 0..14 {
                let mut acc = 0.0f32;
                for dy in 0..2 {
                    for dx in 0..2 {
                        acc += row[(oy * 2 + dy) * 28 + ox * 2 + dx];
                    }
                }
                x.push(acc / 4.0);
            }
        }
    }
    x
}

// ---------------------------------------------------------------------------
// NativeBackend: always-on end-to-end coverage
// ---------------------------------------------------------------------------

/// The acceptance path: a real training loop where every gradient flows
/// through `Backend::loss_and_grad` on the trait object, no artifacts
/// required.
#[test]
fn native_backend_end_to_end_training_reduces_loss() {
    let backend: Box<dyn Backend> = Box::new(NativeBackend::new());
    assert!(backend.available());
    let mlp = sonew::models::Mlp::autoencoder_small();
    let mut rng = Rng::new(21);
    let mut params = mlp.init(&mut rng);
    let hp = HyperParams::default();
    let mut opt = OptSpec::parse("adam")
        .unwrap()
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp)
        .unwrap();
    let mut images = sonew::data::SynthImages::new(22);
    let mut losses = Vec::new();
    for _ in 0..15 {
        let x = pooled_small_batch(&mut images, 16);
        let (loss, g) = backend
            .loss_and_grad("ae_small_grads_b16", &params, vec![HostTensor::F32(x)])
            .unwrap();
        assert_eq!(g.len(), mlp.total);
        assert!(loss.is_finite());
        opt.step(&mut params, &g, 5e-3);
        losses.push(loss);
    }
    let first = losses[0];
    let tail = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
    assert!(
        tail < first,
        "no progress through the backend: {first} -> {tail} ({losses:?})"
    );
}

/// `open_backend` + `BackendAeProvider` + the coordinator's training loop
/// compose over the trait object (full AE, native fallback backend).
#[test]
fn backend_provider_trains_through_coordinator() {
    // a directory with no manifest forces the native fallback even on
    // xla-enabled builds
    let backend = sonew::runtime::open_backend(
        std::env::temp_dir().join("sonew_definitely_missing_artifacts"),
    )
    .unwrap();
    assert!(backend.available());
    let program = "ae_grads_b4".to_string();
    assert!(backend.supports(&program), "{} backend", backend.name());

    let mlp = sonew::models::Mlp::autoencoder();
    let mut rng = Rng::new(31);
    let mut params = mlp.init(&mut rng);
    let hp = HyperParams::default();
    let mut opt = OptSpec::parse("momentum")
        .unwrap()
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp)
        .unwrap();
    let cfg = TrainConfig {
        steps: 2,
        schedule: Schedule::Constant { lr: 1e-3 },
        ..Default::default()
    };
    let provider =
        BackendAeProvider::new(backend, program, sonew::data::SynthImages::new(32), 4);
    let m = train_single(&mut params, &mut opt, provider, &cfg).unwrap();
    assert_eq!(m.points.len(), 2);
    assert!(m.points.iter().all(|p| p.loss.is_finite()));
}

/// Failure injection on the native backend: unknown programs and wrong
/// shapes produce clean errors through the trait object, not panics.
#[test]
fn native_backend_rejects_bad_inputs() {
    let backend: Box<dyn Backend> = Box::new(NativeBackend::new());
    assert!(backend.exec("no_such_artifact", &[]).is_err());
    assert!(!backend.supports("no_such_artifact"));
    let err = backend
        .exec("ae_small_grads_b16", &[HostTensor::F32(vec![1.0])])
        .unwrap_err();
    assert!(format!("{err}").contains("inputs"), "{err}");
    // tridiag executes fine with 4 inputs but returns 3 outputs, which
    // is not a (loss, grads) pair — the trait-default arity check fires
    let t = vec![0.0f32; 4];
    let err = backend
        .loss_and_grad(
            "sonew_tridiag_x",
            &t,
            vec![
                HostTensor::F32(t.clone()),
                HostTensor::F32(t.clone()),
                HostTensor::F32(t.clone()),
            ],
        )
        .unwrap_err();
    assert!(format!("{err}").contains("outputs"), "{err}");
}

/// The Figure-3 pipeline end to end without artifacts: next-token batches
/// from the synthetic corpus, gradients through the native transformer's
/// `lm_grads` program on the trait object, Adam updates — log-perplexity
/// must fall below the untrained starting point.
#[test]
fn native_lm_end_to_end_training_reduces_loss() {
    let backend: Box<dyn Backend> = Box::new(NativeBackend::new());
    assert!(backend.supports("lm_small_grads"));
    assert!(backend.supports("lm_grads"), "Figure-3 program missing from the native zoo");
    let model = sonew::models::Transformer::new(sonew::models::LmConfig::small());
    let cfg = model.cfg;
    let mut params = model.init(17);
    let blocks = sonew::optim::blocks_of(&model.layout);
    let mats = sonew::optim::mat_blocks_of(&model.layout);
    let hp = HyperParams::default();
    let mut opt = OptSpec::parse("adam")
        .unwrap()
        .build(model.total, &blocks, &mats, &hp)
        .unwrap();
    let mut corpus = sonew::data::LmCorpus::new(cfg.vocab, 18);
    let mut losses = Vec::new();
    for _ in 0..15 {
        let (toks, tgts) = corpus.batch(8, cfg.seq);
        let (loss, g) = backend
            .loss_and_grad(
                "lm_small_grads",
                &params,
                vec![HostTensor::I32(toks), HostTensor::I32(tgts)],
            )
            .unwrap();
        assert_eq!(g.len(), model.total);
        assert!(loss.is_finite());
        opt.step(&mut params, &g, 1e-2);
        losses.push(loss);
    }
    let first = losses[0];
    let tail = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
    assert!(
        tail < first,
        "no LM progress through the backend: {first} -> {tail} ({losses:?})"
    );
    // the loss-only eval program agrees with the grads program's loss
    let (toks, tgts) = corpus.batch(2, cfg.seq);
    let out = backend
        .exec(
            "lm_small_loss",
            &[
                HostTensor::F32(params.clone()),
                HostTensor::I32(toks.clone()),
                HostTensor::I32(tgts.clone()),
            ],
        )
        .unwrap();
    let (want, _) = backend
        .loss_and_grad(
            "lm_small_grads",
            &params,
            vec![HostTensor::I32(toks), HostTensor::I32(tgts)],
        )
        .unwrap();
    assert_eq!(out[0].as_f32().unwrap(), &[want][..]);
}

/// Grafted tridiag-SONew through the full optimizer stack trains the
/// (native) small AE — the Table 2 pipeline end to end without artifacts.
#[test]
fn full_optimizer_stack_trains_small_ae() {
    let mlp = sonew::models::Mlp::autoencoder_small();
    let mut rng = Rng::new(2);
    let mut params = mlp.init(&mut rng);
    let hp = HyperParams { gamma: 1e-8, ..Default::default() };
    let mut opt = OptSpec::parse("tridiag-sonew")
        .unwrap()
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp)
        .unwrap();
    let mut images = sonew::data::SynthImages::new(9);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..25 {
        let data = pooled_small_batch(&mut images, 32);
        let xm = sonew::linalg::Mat::from_rows(32, 196, data);
        let (loss, g) = mlp.loss_and_grad(&params, &xm);
        opt.step(&mut params, &g, 5e-3);
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    assert!(last < 0.95 * first.unwrap(), "{:?} -> {last}", first);
}

// ---------------------------------------------------------------------------
// PJRT parity (xla feature + artifacts)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
fn pjrt() -> Option<Box<dyn Backend>> {
    let dir = sonew::runtime::default_artifacts_dir();
    if !sonew::runtime::artifacts_available(&dir) {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    let backend = sonew::runtime::open_backend(dir).expect("open artifacts");
    assert_eq!(backend.name(), "pjrt");
    Some(backend)
}

/// The Pallas tridiag kernel inside the HLO artifact must agree with the
/// native Rust kernel over a multi-step (H, g) stream — the SONew hot
/// path exists twice by design (DESIGN.md §6) and must be bit-comparable.
#[cfg(feature = "xla")]
#[test]
fn sonew_hlo_pallas_matches_native() {
    let Some(backend) = pjrt() else { return };
    let man = backend.manifest().expect("pjrt backend exposes its manifest");
    let spec = man.artifact("sonew_tridiag_ae_small").unwrap().clone();
    let n = spec.inputs[0].elements();
    let beta2 = spec.meta_f64("beta2").unwrap() as f32;
    let eps = spec.meta_f64("eps").unwrap() as f32;
    let gamma = spec.meta_f64("gamma").unwrap_or(0.0) as f32;
    let tids = man.layout("ae_small").unwrap().tensor_ids();

    let mut native = TridiagState::new(n, Some(&tids));
    let mut hd = vec![0.0f32; n];
    let mut ho = vec![0.0f32; n];
    let mut u_native = vec![0.0f32; n];
    let mut rng = Rng::new(11);

    for step in 0..4 {
        let g = rng.normal_vec(n);
        let out = backend
            .exec(
                "sonew_tridiag_ae_small",
                &[
                    HostTensor::F32(hd.clone()),
                    HostTensor::F32(ho.clone()),
                    HostTensor::F32(g.clone()),
                    HostTensor::F32(tids.clone()),
                ],
            )
            .unwrap();
        let hd2 = out[0].as_f32().unwrap();
        let ho2 = out[1].as_f32().unwrap();
        let u_hlo = out[2].as_f32().unwrap();

        native.step(&g, &mut u_native, LambdaMode::Ema(beta2), eps, gamma, Precision::F32);

        let (nhd, nho) = (native.hd.to_f32_vec(), native.ho.to_f32_vec());
        assert!(
            max_rel_err(hd2, &nhd) < 1e-5,
            "step {step}: hd diverged ({})",
            max_rel_err(hd2, &nhd)
        );
        assert!(
            max_rel_err(ho2, &nho) < 1e-5,
            "step {step}: ho diverged ({})",
            max_rel_err(ho2, &nho)
        );
        // Early-step statistics are near-degenerate (rank ~ t), so the
        // 1/schur amplification magnifies fp32 ordering differences on a
        // few lanes; require tight global alignment + bounded worst lane.
        let cos = sonew::linalg::dot(u_hlo, &u_native)
            / (sonew::linalg::norm2(u_hlo) * sonew::linalg::norm2(&u_native));
        assert!(cos > 0.9999, "step {step}: direction cos {cos}");
        assert!(
            max_rel_err(u_hlo, &u_native) < 5e-2,
            "step {step}: direction diverged ({})",
            max_rel_err(u_hlo, &u_native)
        );
        hd = hd2.to_vec();
        ho = ho2.to_vec();
    }
}

/// The HLO grads program and the native Rust MLP compute the same model:
/// identical parameters + identical batch => matching loss and gradients.
#[cfg(feature = "xla")]
#[test]
fn hlo_grads_match_native_mlp() {
    let Some(backend) = pjrt() else { return };
    let man = backend.manifest().unwrap();
    let spec = man.artifact("ae_small_grads_b64").unwrap().clone();
    let n = spec.inputs[0].elements();
    let batch_elems = spec.inputs[1].elements();
    let pixels = spec.inputs[1].dims[1];
    let batch = batch_elems / pixels;

    let mlp = sonew::models::Mlp::autoencoder_small();
    assert_eq!(mlp.total, n, "layout mismatch between python and rust");
    let mut rng = Rng::new(5);
    let params = mlp.init(&mut rng);
    let x_flat = rng.uniform_vec(batch_elems, 0.0, 1.0);

    let (loss_hlo, grads_hlo) = backend
        .loss_and_grad("ae_small_grads_b64", &params, vec![HostTensor::F32(x_flat.clone())])
        .unwrap();
    let x = sonew::linalg::Mat::from_rows(batch, pixels, x_flat);
    let (loss_native, grads_native) = mlp.loss_and_grad(&params, &x);

    assert!(
        (loss_hlo - loss_native).abs() < 1e-2 * loss_native.abs().max(1.0),
        "loss: hlo {loss_hlo} vs native {loss_native}"
    );
    assert!(
        max_rel_err(&grads_hlo, &grads_native) < 1e-3,
        "grads diverged: {}",
        max_rel_err(&grads_hlo, &grads_native)
    );
}

/// End-to-end smoke on the deployment path: HLO grads + HLO Pallas SONew
/// update + rust coordinator reduce the AE loss.
#[cfg(feature = "xla")]
#[test]
fn hlo_end_to_end_training_reduces_loss() {
    let Some(backend) = pjrt() else { return };
    let man = backend.manifest().unwrap();
    let spec = man.artifact("ae_small_grads_b64").unwrap().clone();
    let n = spec.inputs[0].elements();
    let pixels = spec.inputs[1].dims[1];
    let batch = spec.inputs[1].elements() / pixels;
    let tids = man.layout("ae_small").unwrap().tensor_ids();

    let mlp = sonew::models::Mlp::autoencoder_small();
    let mut rng = Rng::new(7);
    let mut params = mlp.init(&mut rng);
    let mut images = sonew::data::SynthImages::new(3);

    let mut hd = vec![0.0f32; n];
    let mut ho = vec![0.0f32; n];
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..12 {
        let x = pooled_small_batch(&mut images, batch);
        let (loss, grads) = backend
            .loss_and_grad("ae_small_grads_b64", &params, vec![HostTensor::F32(x)])
            .unwrap();
        let out = backend
            .exec(
                "sonew_tridiag_ae_small",
                &[
                    HostTensor::F32(std::mem::take(&mut hd)),
                    HostTensor::F32(std::mem::take(&mut ho)),
                    HostTensor::F32(grads),
                    HostTensor::F32(tids.clone()),
                ],
            )
            .unwrap();
        let mut it = out.into_iter();
        hd = it.next().unwrap().into_f32().unwrap();
        ho = it.next().unwrap().into_f32().unwrap();
        let mut u = it.next().unwrap().into_f32().unwrap();
        // gradient-norm grafting (§5): early rank-deficient statistics
        // make the raw Newton direction enormous; the paper always runs
        // SONew with a grafted step magnitude.
        let gn = {
            // recompute ||g|| from the statistics innovation is overkill;
            // normalize u to unit norm and use a fixed trust region.
            let un = sonew::linalg::norm2(&u);
            if un > 1e-30 { 1.0 / un } else { 0.0 }
        };
        for (p, &ui) in params.iter_mut().zip(&u) {
            *p -= 0.05 * ui * gn;
        }
        u.clear();
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
        assert!(loss.is_finite());
    }
    let first = first.unwrap();
    assert!(last < first, "no progress: {first} -> {last}");
}

/// Banded artifact parity on the small AE.
#[cfg(feature = "xla")]
#[test]
fn sonew_banded_hlo_matches_native() {
    let Some(backend) = pjrt() else { return };
    let man = backend.manifest().unwrap();
    let Ok(spec) = man.artifact("sonew_band4_ae_small") else { return };
    let spec = spec.clone();
    let n = spec.inputs[1].elements();
    let b = spec.inputs[0].dims[0] - 1;
    let beta2 = spec.meta_f64("beta2").unwrap() as f32;
    let eps = spec.meta_f64("eps").unwrap() as f32;
    let tids = man.layout("ae_small").unwrap().tensor_ids();

    let mut native = sonew::sonew::BandedState::new(n, b, Some(&tids));
    let mut diags = vec![0.0f32; (b + 1) * n];
    let mut u_native = vec![0.0f32; n];
    let mut rng = Rng::new(13);
    for step in 0..2 {
        let g = rng.normal_vec(n);
        let out = backend
            .exec(
                "sonew_band4_ae_small",
                &[
                    HostTensor::F32(diags.clone()),
                    HostTensor::F32(g.clone()),
                    HostTensor::F32(tids.clone()),
                ],
            )
            .unwrap();
        let d2 = out[0].as_f32().unwrap();
        let u_hlo = out[1].as_f32().unwrap();
        native.step(&g, &mut u_native, LambdaMode::Ema(beta2), eps, 0.0, Precision::F32);
        let native_flat: Vec<f32> =
            native.diags.iter().flat_map(|d| d.to_f32_vec()).collect();
        assert!(
            max_rel_err(d2, &native_flat) < 1e-4,
            "step {step}: banded stats diverged ({})",
            max_rel_err(d2, &native_flat)
        );
        assert!(
            max_rel_err(u_hlo, &u_native) < 5e-3,
            "step {step}: banded direction diverged ({})",
            max_rel_err(u_hlo, &u_native)
        );
        diags = d2.to_vec();
    }
}

/// Failure injection: wrong shapes and unknown artifacts produce clean
/// errors through the PJRT backend, not aborts.
#[cfg(feature = "xla")]
#[test]
fn engine_rejects_bad_inputs() {
    let Some(backend) = pjrt() else { return };
    assert!(backend.exec("no_such_artifact", &[]).is_err());
    let err = backend
        .exec("sonew_tridiag_ae_small", &[HostTensor::F32(vec![1.0])])
        .unwrap_err();
    assert!(format!("{err}").contains("inputs"), "{err}");
    let man = backend.manifest().unwrap();
    let spec = man.artifact("sonew_tridiag_ae_small").unwrap().clone();
    let n = spec.inputs[0].elements();
    let err = backend
        .exec(
            "sonew_tridiag_ae_small",
            &[
                HostTensor::F32(vec![0.0; n]),
                HostTensor::F32(vec![0.0; n]),
                HostTensor::F32(vec![0.0; 3]), // wrong length
                HostTensor::F32(vec![0.0; n]),
            ],
        )
        .unwrap_err();
    assert!(format!("{err}").contains("elements"), "{err}");
}
