//! Online-serving determinism contract (ISSUE 8): a replayed request
//! log reproduces model state bitwise for any shard count, the durable
//! store resumes exactly, and progressive validation actually measures
//! learning. CI runs this suite at `SONEW_THREADS=1` and `=4`, so the
//! shard fan-out is exercised both self-drained and cross-thread.

use sonew::data::requests::SynthRequests;
use sonew::optim::{HyperParams, OptSpec};
use sonew::serving::{replay, ModelStore, StoreConfig};

fn cfg(spec: &str, dim: usize, dir: Option<std::path::PathBuf>) -> StoreConfig {
    StoreConfig {
        dir,
        dim,
        // ONS directions are already curvature-scaled; dense first/second
        // order baselines want a small step
        lr: if spec == "sparse-ons" { 1.0 } else { 0.05 },
        spec: OptSpec::parse(spec).unwrap(),
        base: HyperParams { eps: 1.0, ..Default::default() },
        checkpoint_every: 0,
    }
}

/// Sorted per-model (id, updates, exact param bits) — the state surface
/// the determinism contract is about.
fn fingerprints(store: &ModelStore) -> Vec<(String, u64, Vec<u32>)> {
    store
        .model_ids()
        .iter()
        .map(|id| {
            let m = store.model(id).expect("listed id");
            (id.clone(), m.updates(), m.params().iter().map(|w| w.to_bits()).collect())
        })
        .collect()
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sonew_serve_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

#[test]
fn replay_is_shard_count_invariant() {
    // same log, shard counts 1 / 2 / 5 (more shards than models is
    // legal): final params, outcomes, curve and summary all bitwise
    let log = SynthRequests::new(13, 5, 48, 4).take(240);
    for spec in ["sparse-ons", "adam", "tridiag-sonew"] {
        let mut reference = None;
        for shards in [1usize, 2, 5] {
            let mut store = ModelStore::open(cfg(spec, 48, None), shards).unwrap();
            let report = replay(&mut store, &log, 50).unwrap();
            assert_eq!(report.outcomes.len(), log.len());
            let got = (fingerprints(&store), report.outcomes, report.curve, report.summary);
            match &reference {
                None => reference = Some(got),
                Some(want) => {
                    assert_eq!(got.0, want.0, "{spec} @ {shards} shards: params diverged");
                    assert_eq!(got.1, want.1, "{spec} @ {shards} shards: outcomes diverged");
                    assert_eq!(got.2, want.2, "{spec} @ {shards} shards: curve diverged");
                    assert_eq!(got.3, want.3, "{spec} @ {shards} shards: summary diverged");
                }
            }
        }
    }
}

#[test]
fn store_resume_matches_the_uninterrupted_run() {
    // half the log, flush, reopen under a different shard count, second
    // half: final state must equal the one-shot replay bitwise
    let dim = 32;
    let log = SynthRequests::new(29, 3, dim, 3).take(160);
    for spec in ["sparse-ons", "adam"] {
        let mut oneshot = ModelStore::open(cfg(spec, dim, None), 2).unwrap();
        replay(&mut oneshot, &log, 40).unwrap();
        let want = fingerprints(&oneshot);

        let dir = tmpdir(spec);
        let mut first = ModelStore::open(cfg(spec, dim, Some(dir.clone())), 3).unwrap();
        replay(&mut first, &log[..80], 40).unwrap();
        first.flush().unwrap();
        drop(first);
        let mut second = ModelStore::open(cfg(spec, dim, Some(dir.clone())), 1).unwrap();
        assert_eq!(second.len(), 3, "{spec}: reopened store lost models");
        replay(&mut second, &log[80..], 40).unwrap();
        assert_eq!(fingerprints(&second), want, "{spec}: resumed serve diverged");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn background_checkpoints_survive_an_unflushed_drop() {
    // periodic background writes are durable on their own: drop the
    // store without flush and the periodic snapshots are still loadable
    let dim = 16;
    let log = SynthRequests::new(3, 2, dim, 3).take(40);
    let dir = tmpdir("bg");
    let mut c = cfg("sparse-ons", dim, Some(dir.clone()));
    c.checkpoint_every = 5;
    let mut store = ModelStore::open(c, 2).unwrap();
    replay(&mut store, &log, 10).unwrap();
    drop(store); // JobHandle Drop is a completion barrier; no flush
    let back = ModelStore::open(cfg("sparse-ons", dim, Some(dir.clone())), 1).unwrap();
    assert_eq!(back.len(), 2);
    for id in back.model_ids() {
        let m = back.model(&id).unwrap();
        assert!(m.updates() >= 15, "{id}: periodic snapshot too old ({})", m.updates());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn progressive_validation_improves_on_a_separable_stream() {
    let log = SynthRequests::new(7, 2, 64, 6).take(600);
    let mut store = ModelStore::open(cfg("sparse-ons", 64, None), 4).unwrap();
    let report = replay(&mut store, &log, 100).unwrap();
    let s = report.summary;
    assert_eq!(s.requests, 600);
    // the stream is linearly separable per model — the online learner
    // must clearly beat coin flipping and the p=0.5 logloss (ln 2)
    assert!(s.accuracy > 0.55, "cumulative accuracy {}", s.accuracy);
    assert!(s.mean_loss < 0.68, "cumulative logloss {}", s.mean_loss);
    let last = report.curve.last().unwrap();
    assert!(last.accuracy > 0.55, "late accuracy {}", last.accuracy);
}
