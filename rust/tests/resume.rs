//! Exact-resume acceptance tests: a `TrainSession` checkpointed at step
//! N/2 (v2 format: params + optimizer state + data-stream RNG) and
//! reloaded into a freshly-constructed session — the fresh-process path:
//! nothing survives but the file — must reproduce the uninterrupted
//! N-step run *bitwise*: identical parameters and identical loss
//! trajectory, for a first-order (Adam), a SONew (tridiag) and a
//! Kronecker (Shampoo) optimizer.

use sonew::coordinator::trainer::NativeAeProvider;
use sonew::coordinator::{Schedule, SessionConfig, TrainConfig, TrainSession};
use sonew::data::SynthImages;
use sonew::models::Mlp;
use sonew::optim::{HyperParams, OptSpec};
use sonew::util::Rng;

const STEPS: u64 = 12;

/// Build a complete fresh session from nothing but the spec — the same
/// construction path a new process would run.
fn fresh_session(
    spec: &OptSpec,
    resume_from: Option<std::path::PathBuf>,
) -> TrainSession<NativeAeProvider> {
    let mlp = Mlp::new(&[49, 24, 12, 24, 49]);
    let mut rng = Rng::new(7);
    let params = mlp.init(&mut rng);
    let hp = HyperParams { gamma: 1e-8, ..Default::default() };
    let opt = spec
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp)
        .unwrap();
    let provider = NativeAeProvider::new(mlp.clone(), SynthImages::new(5), 8);
    TrainSession::new(
        spec.clone(),
        opt,
        params,
        provider,
        SessionConfig {
            train: TrainConfig {
                steps: STEPS,
                schedule: Schedule::CosineWarmup {
                    lr: 2e-3,
                    warmup: 2,
                    total: STEPS,
                    final_frac: 0.1,
                },
                log_every: 1,
                ..Default::default()
            },
            resume_from,
            ..Default::default()
        },
    )
    .unwrap()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn assert_exact_resume(spec_str: &str) {
    let spec = OptSpec::parse(spec_str).unwrap();
    let dir = std::env::temp_dir().join(format!("sonew_resume_{}", spec.name()));
    let path = dir.join("half.ck");

    // uninterrupted run: N steps straight
    let mut straight = fresh_session(&spec, None);
    let m_straight = straight.run().unwrap();

    // interrupted run: N/2 steps, checkpoint, drop everything
    {
        let mut first_half = fresh_session(&spec, None);
        let m_first = first_half.run_steps(STEPS / 2).unwrap();
        first_half.checkpoint(&path).unwrap();
        // the first half already matches the straight run step for step
        for (a, b) in m_first.points.iter().zip(&m_straight.points) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{spec_str}: pre-checkpoint loss diverged at step {}",
                a.step
            );
        }
    }

    // fresh construction + restore from the file (fresh-process path)
    let mut resumed = fresh_session(&spec, Some(path.clone()));
    assert_eq!(resumed.step, STEPS / 2, "{spec_str}");
    assert_eq!(resumed.opt.steps(), STEPS / 2, "{spec_str}");
    let m_resumed = resumed.run().unwrap();

    // params bitwise identical
    assert_eq!(
        bits(&resumed.params),
        bits(&straight.params),
        "{spec_str}: resumed params differ from the uninterrupted run"
    );
    // and the post-resume loss trajectory matches the straight run's
    // second half bitwise
    let tail: Vec<_> = m_straight
        .points
        .iter()
        .filter(|p| p.step >= STEPS / 2)
        .collect();
    assert_eq!(m_resumed.points.len(), tail.len(), "{spec_str}");
    for (a, b) in m_resumed.points.iter().zip(tail) {
        assert_eq!(a.step, b.step, "{spec_str}");
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "{spec_str}: resumed loss diverged at step {}",
            a.step
        );
        assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "{spec_str}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn tridiag_sonew_resumes_bitwise() {
    assert_exact_resume("tridiag-sonew");
}

#[test]
fn adam_resumes_bitwise() {
    assert_exact_resume("adam");
}

#[test]
fn shampoo_resumes_bitwise() {
    // interval 3 forces a preconditioner refresh both before and after
    // the checkpoint boundary, exercising the cached-root persistence
    assert_exact_resume("shampoo:interval=3");
}

#[test]
fn resume_rejects_a_different_spec() {
    let spec = OptSpec::parse("adam").unwrap();
    let dir = std::env::temp_dir().join("sonew_resume_mismatch");
    let path = dir.join("a.ck");
    let mut s = fresh_session(&spec, None);
    s.run_steps(2).unwrap();
    s.checkpoint(&path).unwrap();
    let other = OptSpec::parse("tridiag-sonew").unwrap();
    let mut t = fresh_session(&other, None);
    let err = t.restore(&path).unwrap_err();
    assert!(format!("{err:#}").contains("adam"), "{err:#}");
    std::fs::remove_dir_all(dir).ok();
}
