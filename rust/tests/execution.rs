//! Execution API v1 acceptance tests.
//!
//! 1. Sweep sharding determinism: the parallel `SweepScheduler` at
//!    1/2/max workers returns the identical best trial, objective and
//!    evaluated/discarded counts as serial `random_search` with the
//!    same seed — on a real (miniature) training objective.
//! 2. Executor stress: `run_chunked` over the persistent pool matches
//!    inline execution under concurrent mixed-size load, and the
//!    GEMM / SONew kernels stay bitwise-deterministic while the pool
//!    is shared and busy.

use sonew::coordinator::sweep::{random_search, SearchSpace, SweepScheduler, Trial};
use sonew::coordinator::{Schedule, TrainConfig, TrainSession};
use sonew::optim::{HyperParams, OptSpec};

/// Miniature of the CLI sweep objective: a fixed-seed small-AE training
/// run — deterministic per trial by construction (fixed seeds, bitwise
/// kernels at any thread count), with a deterministic divergence band
/// so discard accounting is exercised.
fn ae_objective(trial: &Trial) -> f32 {
    // the band sits at the search box's log-median, so a 12-trial sweep
    // all but surely samples both sides of it
    if trial.lr > 1e-4 {
        return f32::NAN;
    }
    let mlp = sonew::models::Mlp::new(&[49, 24, 49]);
    let mut rng = sonew::util::Rng::new(0);
    let params = mlp.init(&mut rng);
    let mats = sonew::tables::autoencoder::cap_mat_blocks(&mlp.mat_blocks(), 128);
    let mut opt = match trial.build(mlp.total, &mlp.blocks(), &mats) {
        Ok(o) => o,
        Err(_) => return f32::NAN,
    };
    let tc = TrainConfig {
        steps: 4,
        schedule: Schedule::Constant { lr: trial.lr },
        ..Default::default()
    };
    let provider = sonew::coordinator::trainer::NativeAeProvider::new(
        mlp.clone(),
        sonew::data::SynthImages::new(1),
        16,
    );
    match TrainSession::ephemeral(&mut opt, params, provider, tc).finish() {
        Ok((_, m)) => m.tail_mean_loss(2).unwrap_or(f32::NAN),
        Err(_) => f32::NAN,
    }
}

#[test]
fn sweep_sharding_reproduces_serial_bitwise() {
    let spec = OptSpec::parse("adam").unwrap();
    let space = SearchSpace::default();
    let base = HyperParams::default();
    let trials = 12;
    let seed = 7;
    let serial = random_search(&spec, &space, &base, trials, seed, ae_objective).unwrap();
    assert!(serial.discarded > 0, "divergence band never hit; weak test");
    assert!(serial.evaluated > 0);
    let max = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    for workers in [1usize, 2, max.max(3)] {
        let par = SweepScheduler::new(workers)
            .run(&spec, &space, &base, trials, seed, ae_objective)
            .unwrap();
        assert_eq!(par.best_index, serial.best_index, "workers={workers}");
        assert_eq!(
            par.best_objective.to_bits(),
            serial.best_objective.to_bits(),
            "workers={workers}"
        );
        assert_eq!(par.best.lr.to_bits(), serial.best.lr.to_bits(), "workers={workers}");
        assert_eq!(
            par.best.hp.beta1.to_bits(),
            serial.best.hp.beta1.to_bits(),
            "workers={workers}"
        );
        assert_eq!(
            par.best.hp.beta2.to_bits(),
            serial.best.hp.beta2.to_bits(),
            "workers={workers}"
        );
        assert_eq!(par.best.hp.eps.to_bits(), serial.best.hp.eps.to_bits(), "workers={workers}");
        assert_eq!(par.evaluated, serial.evaluated, "workers={workers}");
        assert_eq!(par.discarded, serial.discarded, "workers={workers}");
        assert_eq!(par.trials.len(), serial.trials.len(), "workers={workers}");
        for (a, b) in par.trials.iter().zip(&serial.trials) {
            assert_eq!(a.index, b.index, "workers={workers}");
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "workers={workers}");
            assert_eq!(a.diverged, b.diverged, "workers={workers}");
        }
    }
}

#[test]
fn run_chunked_over_executor_matches_inline_under_stress() {
    // hammer the persistent pool from several threads at once with
    // mixed-size batches at mixed thread counts; every fan-out must
    // produce exactly the inline (threads = 1) result
    std::thread::scope(|s| {
        for t in 0..4u64 {
            s.spawn(move || {
                for round in 0..50usize {
                    let n = 1 + (round * 7 + t as usize) % 97;
                    let mut out = vec![0u64; n];
                    let items: Vec<(usize, &mut u64)> = out.iter_mut().enumerate().collect();
                    sonew::util::par::run_chunked(items, 1 + round % 8, |(i, slot)| {
                        *slot = (t + 1) * (i as u64 + 1);
                    });
                    for (i, &v) in out.iter().enumerate() {
                        assert_eq!(v, (t + 1) * (i as u64 + 1), "t={t} round={round} i={i}");
                    }
                }
            });
        }
    });
}

#[test]
fn gemm_and_sonew_stay_bitwise_on_the_shared_pool() {
    use sonew::linalg::{matmul, Mat};
    use sonew::sonew::{LambdaMode, TridiagState};
    use sonew::util::Precision;

    // the same GEMM recomputed concurrently on the shared pool (past
    // the 2e6-flop parallel gate) must return identical bits every time
    let mut rng = sonew::util::Rng::new(3);
    let a = Mat::from_rows(128, 128, rng.normal_vec(128 * 128));
    let b = Mat::from_rows(128, 128, rng.normal_vec(128 * 128));
    let want = matmul(&a, &b);
    std::thread::scope(|s| {
        for _ in 0..3 {
            let (a, b, want) = (&a, &b, &want);
            s.spawn(move || {
                for _ in 0..20 {
                    let c = matmul(a, b);
                    assert!(
                        c.data.iter().zip(&want.data).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "GEMM drifted under concurrent pool load"
                    );
                }
            });
        }
    });

    // tridiag block-parallel step (pool path) equals pinned-sequential
    let n = 16 * 1024;
    let ids: Vec<f32> = (0..n).map(|j| (j * 8 / n) as f32).collect();
    let g = sonew::util::Rng::new(4).normal_vec(n);
    let mut u_seq = vec![0.0f32; n];
    let mut u_par = vec![0.0f32; n];
    let mut st_seq = TridiagState::new(n, Some(&ids));
    st_seq.parallel = false;
    let mut st_par = TridiagState::new(n, Some(&ids));
    for _ in 0..3 {
        st_seq.step(&g, &mut u_seq, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
        st_par.step(&g, &mut u_par, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
    }
    assert!(
        u_seq.iter().zip(&u_par).all(|(x, y)| x.to_bits() == y.to_bits()),
        "SONew block-parallel scan drifted from sequential on the pool"
    );
}
