//! Distributed acceptance tests: the multi-process determinism contract.
//!
//! (a) A data-parallel `TrainSession` over a real communicator — thread
//!     endpoints or localhost TCP — at world sizes 1/2/4 produces a loss
//!     trajectory, final params and checkpoint bytes **bitwise identical**
//!     to the serial reference (the same session at world 1), at any
//!     `SONEW_THREADS`.
//! (b) `sonew sweep --hosts 2` reproduces the serial sweep's best trial,
//!     objective and per-trial CSV byte-for-byte; `sonew train --hosts 2`
//!     reproduces the `--hosts 1` `[dp]` fingerprint and checkpoint.
//! (c) A killed worker surfaces as a shard-naming error within the read
//!     timeout — never a hang.

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sonew::comm::{thread, Communicator, LocalComm, TcpComm, TcpConfig};
use sonew::coordinator::trainer::NativeAeProvider;
use sonew::coordinator::{Schedule, SessionConfig, TrainConfig, TrainSession};
use sonew::data::SynthImages;
use sonew::models::Mlp;
use sonew::optim::{HyperParams, OptSpec};
use sonew::util::Rng;

const STEPS: u64 = 8;
const SHARDS: usize = 4;
const BATCH: usize = 16;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sonew-dist-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Run one rank of the shared data-parallel session: every caller builds
/// the *identical* session (same seeds, same provider) and only the
/// communicator endpoint differs. Returns (loss-trace bits, param bits).
fn dp_run(comm: Arc<dyn Communicator>, ck: Option<PathBuf>) -> (Vec<u32>, Vec<u32>) {
    let spec = OptSpec::parse("tridiag-sonew").unwrap();
    let mlp = Mlp::new(&[49, 24, 12, 24, 49]);
    let mut rng = Rng::new(7);
    let params = mlp.init(&mut rng);
    let hp = HyperParams { gamma: 1e-8, ..Default::default() };
    let opt = spec
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp)
        .unwrap();
    let provider = NativeAeProvider::new(mlp.clone(), SynthImages::new(5), BATCH);
    let mut session = TrainSession::new(
        spec,
        opt,
        params,
        provider,
        SessionConfig {
            train: TrainConfig {
                steps: STEPS,
                schedule: Schedule::Constant { lr: 2e-3 },
                ..Default::default()
            },
            checkpoint_every: if ck.is_some() { 4 } else { 0 },
            checkpoint_path: ck.clone(),
            pipeline: false,
            comm: Some(comm),
            grad_shards: SHARDS,
            ..Default::default()
        },
    )
    .unwrap();
    let m = session.run().unwrap();
    if let Some(path) = &ck {
        // collective: rank 0 writes, everyone holds at the barrier
        session.checkpoint(path).unwrap();
    }
    let losses: Vec<u32> = m.points.iter().map(|p| p.loss.to_bits()).collect();
    (losses, bits(&session.params))
}

/// Run `f` on every rank of a real localhost-TCP world (hub = rank 0 on
/// this thread, workers on scoped threads), returning rank-ordered results.
fn tcp_world<R: Send>(world: usize, f: impl Fn(Arc<dyn Communicator>) -> R + Sync) -> Vec<R> {
    let (listener, addr) = TcpComm::bind().unwrap();
    std::thread::scope(|s| {
        let addr = addr.to_string();
        let mut handles = Vec::new();
        for rank in 1..world {
            let addr = addr.clone();
            let f = &f;
            handles.push(s.spawn(move || {
                let (comm, job) =
                    TcpComm::connect(&addr, rank, world, TcpConfig::default()).unwrap();
                assert!(job.is_empty(), "this world ships no job payload");
                f(Arc::new(comm))
            }));
        }
        let hub = TcpComm::host(listener, world, &[], TcpConfig::default()).unwrap();
        let mut out = vec![f(Arc::new(hub))];
        for h in handles {
            out.push(h.join().unwrap());
        }
        out
    })
}

#[test]
fn dp_training_is_bitwise_identical_across_world_sizes() {
    let dir = tmp_dir("worlds");
    let ck1 = dir.join("w1.ck");
    let reference = dp_run(Arc::new(LocalComm), Some(ck1.clone()));
    let ck_ref = std::fs::read(&ck1).unwrap();
    for world in [2usize, 4] {
        let ck = dir.join(format!("thread-w{world}.ck"));
        let got = thread::run_world(world, |comm| dp_run(Arc::new(comm), Some(ck.clone())));
        for (rank, g) in got.iter().enumerate() {
            assert_eq!(g, &reference, "thread world={world} rank={rank}");
        }
        assert_eq!(std::fs::read(&ck).unwrap(), ck_ref, "thread world={world} checkpoint");
    }
    for world in [2usize, 4] {
        let ck = dir.join(format!("tcp-w{world}.ck"));
        let got = tcp_world(world, |comm| dp_run(comm, Some(ck.clone())));
        for (rank, g) in got.iter().enumerate() {
            assert_eq!(g, &reference, "tcp world={world} rank={rank}");
        }
        assert_eq!(std::fs::read(&ck).unwrap(), ck_ref, "tcp world={world} checkpoint");
    }
    std::fs::remove_dir_all(&dir).ok();
}

fn run_sonew(dir: &Path, args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_sonew"))
        .args(args)
        .env("SONEW_RESULTS", dir.join("results"))
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "sonew {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

#[test]
fn sweep_hosts_reproduces_the_serial_sweep_byte_for_byte() {
    let dir = tmp_dir("sweep");
    let serial_csv = dir.join("serial.csv");
    let hosts_csv = dir.join("hosts.csv");
    let common = ["sweep", "--opt", "adam", "--trials", "6", "--steps", "3", "--seed", "9"];
    let mut serial: Vec<&str> = common.to_vec();
    serial.extend(["--workers", "1", "--csv", serial_csv.to_str().unwrap()]);
    let mut hosts: Vec<&str> = common.to_vec();
    hosts.extend(["--hosts", "2", "--csv", hosts_csv.to_str().unwrap()]);
    let serial_out = run_sonew(&dir, &serial);
    let hosts_out = run_sonew(&dir, &hosts);
    let best = |s: &str| s.lines().find(|l| l.starts_with("[sweep] best")).map(str::to_string);
    assert!(best(&serial_out).is_some(), "no best line in: {serial_out}");
    assert_eq!(best(&serial_out), best(&hosts_out), "best-trial summary must match");
    assert_eq!(
        std::fs::read(&serial_csv).unwrap(),
        std::fs::read(&hosts_csv).unwrap(),
        "per-trial CSV must be byte-identical across sharding modes"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_hosts_reproduces_the_serial_dp_fingerprint_and_checkpoint() {
    let dir = tmp_dir("train");
    let ck1 = dir.join("h1.ck");
    let ck2 = dir.join("h2.ck");
    let run = |hosts: &str, ck: &Path| {
        run_sonew(
            &dir,
            &[
                "train", "--opt", "tridiag-sonew", "--small", "--steps", "6", "--batch", "16",
                "--grad-shards", "4", "--seed", "3", "--hosts", hosts, "--checkpoint",
                ck.to_str().unwrap(),
            ],
        )
    };
    let serial_out = run("1", &ck1);
    let hosts_out = run("2", &ck2);
    let dp = |s: &str| -> Vec<String> {
        s.lines().filter(|l| l.starts_with("[dp]")).map(str::to_string).collect()
    };
    assert!(!dp(&serial_out).is_empty(), "no [dp] fingerprint in: {serial_out}");
    assert_eq!(dp(&serial_out), dp(&hosts_out), "[dp] fingerprints must match");
    assert_eq!(
        std::fs::read(&ck1).unwrap(),
        std::fs::read(&ck2).unwrap(),
        "checkpoint bytes must be identical across --hosts values"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn a_killed_worker_surfaces_a_named_error_within_the_timeout() {
    // Hand-assemble the sweep job payload (spec, trials, steps, seed,
    // world — little-endian, strings length-prefixed) with a workload
    // long enough that the worker cannot finish before it is killed.
    let put_u64 = |buf: &mut Vec<u8>, v: u64| buf.extend_from_slice(&v.to_le_bytes());
    let mut job = Vec::new();
    put_u64(&mut job, 4);
    job.extend_from_slice(b"adam");
    put_u64(&mut job, 400); // trials
    put_u64(&mut job, 200); // steps
    put_u64(&mut job, 0); // seed
    put_u64(&mut job, 2); // world
    let (listener, addr) = TcpComm::bind().unwrap();
    let mut child = Command::new(env!("CARGO_BIN_EXE_sonew"))
        .args(["sweep-worker", "--shard", "1/2", "--connect", &addr.to_string()])
        .stdout(Stdio::null())
        .spawn()
        .unwrap();
    let cfg = TcpConfig {
        read_timeout: Duration::from_secs(5),
        peer: "sweep shard".into(),
        ..Default::default()
    };
    let comm = TcpComm::host(listener, 2, &job, cfg).unwrap();
    child.kill().unwrap();
    child.wait().unwrap();
    let t0 = Instant::now();
    let err = comm.gather(&[]).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("sweep shard 1"), "error must name the dead shard: {text}");
    assert!(
        text.contains("disconnected") || text.contains("timed out"),
        "error must say what happened on the wire: {text}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "a dead worker must fail the collective fast, not hang"
    );
}
