//! Staged-pipeline acceptance tests: the determinism contract.
//!
//! `TrainSession` with `cfg.pipeline = true` (prefetch + background
//! checkpoint writer) must be **bitwise identical** to the strictly
//! synchronous loop: same loss trajectory, same final params, same
//! checkpoint bytes on disk. CI runs this suite under both
//! `SONEW_THREADS=1` (zero executor workers — the submitter self-drains)
//! and `SONEW_THREADS=4`, so the contract is exercised at both ends.
//!
//! Also covered: crash-mid-checkpoint recovery — a truncated temp file
//! left by a dead writer is swept on session construction, the last
//! complete checkpoint still loads, and no `.tmp` residue survives.

use std::path::PathBuf;

use sonew::coordinator::trainer::{BackendLmProvider, FnProvider, NativeAeProvider};
use sonew::coordinator::{Schedule, SessionConfig, TrainConfig, TrainSession};
use sonew::data::{LmCorpus, SynthImages};
use sonew::models::Mlp;
use sonew::optim::{HyperParams, OptSpec};
use sonew::util::Rng;

const STEPS: u64 = 10;
const CK_EVERY: u64 = 4;

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Build a checkpointable AE session from nothing but the spec, with the
/// pipeline toggled explicitly.
fn fresh_ae_session(
    spec: &OptSpec,
    pipeline: bool,
    checkpoint_path: Option<PathBuf>,
    resume_from: Option<PathBuf>,
) -> TrainSession<NativeAeProvider> {
    let mlp = Mlp::new(&[49, 24, 12, 24, 49]);
    let mut rng = Rng::new(7);
    let params = mlp.init(&mut rng);
    let hp = HyperParams { gamma: 1e-8, ..Default::default() };
    let opt = spec
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp)
        .unwrap();
    let provider = NativeAeProvider::new(mlp.clone(), SynthImages::new(5), 8);
    TrainSession::new(
        spec.clone(),
        opt,
        params,
        provider,
        SessionConfig {
            train: TrainConfig {
                steps: STEPS,
                schedule: Schedule::CosineWarmup {
                    lr: 2e-3,
                    warmup: 2,
                    total: STEPS,
                    final_frac: 0.1,
                },
                log_every: 1,
                ..Default::default()
            },
            checkpoint_every: if checkpoint_path.is_some() { CK_EVERY } else { 0 },
            checkpoint_path,
            resume_from,
            pipeline,
            ..Default::default()
        },
    )
    .unwrap()
}

/// The contract itself: pipeline on vs off must agree bitwise on the
/// loss trajectory, the learning-rate schedule, the final parameters,
/// and the periodic checkpoint bytes on disk.
fn assert_pipeline_equivalence(spec_str: &str) {
    let spec = OptSpec::parse(spec_str).unwrap();
    let dir = std::env::temp_dir().join(format!("sonew_pipeline_{}", spec.name()));
    std::fs::remove_dir_all(&dir).ok();
    let ck_sync = dir.join("sync.ck");
    let ck_async = dir.join("async.ck");

    let mut sync = fresh_ae_session(&spec, false, Some(ck_sync.clone()), None);
    let m_sync = sync.run().unwrap();

    let mut pipe = fresh_ae_session(&spec, true, Some(ck_async.clone()), None);
    let m_pipe = pipe.run().unwrap();

    assert_eq!(m_sync.points.len(), m_pipe.points.len(), "{spec_str}");
    for (a, b) in m_sync.points.iter().zip(&m_pipe.points) {
        assert_eq!(a.step, b.step, "{spec_str}");
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "{spec_str}: pipelined loss diverged at step {}",
            a.step
        );
        assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "{spec_str}: lr diverged at step {}", a.step);
    }
    assert_eq!(
        bits(&sync.params),
        bits(&pipe.params),
        "{spec_str}: pipelined params differ from the synchronous loop"
    );

    // run_steps is a flush barrier — both files are complete here, and
    // the background writer must have produced byte-identical state
    let a = std::fs::read(&ck_sync).unwrap();
    let b = std::fs::read(&ck_async).unwrap();
    assert_eq!(a, b, "{spec_str}: checkpoint bytes differ between pipeline on/off");

    // and both resume to the same place
    let resumed = fresh_ae_session(&spec, true, None, Some(ck_async.clone()));
    assert_eq!(resumed.step, STEPS - STEPS % CK_EVERY, "{spec_str}");

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn tridiag_sonew_pipeline_is_bitwise_equivalent() {
    assert_pipeline_equivalence("tridiag-sonew");
}

#[test]
fn adam_pipeline_is_bitwise_equivalent() {
    assert_pipeline_equivalence("adam");
}

/// The tensor lane (backend LM provider) honors the same contract: the
/// prefetch worker draws token batches, the training thread keeps the
/// backend — results match the synchronous loop bitwise.
#[test]
fn backend_lm_pipeline_matches_sync_bitwise() {
    let run = |pipeline: bool| {
        let model = sonew::models::Transformer::new(sonew::models::LmConfig::small());
        let cfg_lm = model.cfg;
        let params = model.init(3);
        let hp = HyperParams::default();
        let blocks = sonew::optim::blocks_of(&model.layout);
        let mats = sonew::optim::mat_blocks_of(&model.layout);
        let opt = OptSpec::parse("adam")
            .unwrap()
            .build(model.total, &blocks, &mats, &hp)
            .unwrap();
        let provider = BackendLmProvider::new(
            Box::new(sonew::runtime::NativeBackend::new()),
            "lm_small_grads",
            LmCorpus::new(cfg_lm.vocab, 11),
            2,
            cfg_lm.seq,
        );
        let mut s = TrainSession::ephemeral(
            opt,
            params,
            provider,
            TrainConfig {
                steps: 4,
                schedule: Schedule::Constant { lr: 3e-3 },
                ..Default::default()
            },
        );
        s.cfg.pipeline = pipeline;
        let m = s.run().unwrap();
        (bits(&s.params), m.points.iter().map(|p| p.loss.to_bits()).collect::<Vec<_>>())
    };
    let (p_sync, l_sync) = run(false);
    let (p_pipe, l_pipe) = run(true);
    assert_eq!(l_sync, l_pipe, "LM loss trajectory diverged under the pipeline");
    assert_eq!(p_sync, p_pipe, "LM params diverged under the pipeline");
}

/// Providers without a prepare/consume split (closures) fall back to the
/// one-shot path regardless of the pipeline flag — identical results,
/// no prefetch attempted.
#[test]
fn fn_provider_falls_back_to_the_one_shot_path() {
    let run = |pipeline: bool| {
        let mut rng = Rng::new(9);
        let provider = FnProvider(move |p: &[f32]| -> anyhow::Result<(f32, Vec<f32>)> {
            // deterministic noisy quadratic: grad = p + noise
            let noise = rng.normal_vec(p.len());
            let loss = p.iter().map(|x| 0.5 * x * x).sum::<f32>();
            let grads = p.iter().zip(&noise).map(|(x, n)| x + 0.01 * n).collect();
            Ok((loss, grads))
        });
        let spec = OptSpec::parse("adam").unwrap();
        let opt = spec
            .build(16, &vec![(0, 16)], &sonew::optim::MatBlocks::new(), &HyperParams::default())
            .unwrap();
        let mut s = TrainSession::ephemeral(
            opt,
            vec![1.0f32; 16],
            provider,
            TrainConfig {
                steps: 6,
                schedule: Schedule::Constant { lr: 1e-2 },
                ..Default::default()
            },
        );
        s.cfg.pipeline = pipeline;
        let m = s.run().unwrap();
        (bits(&s.params), m.points.iter().map(|p| p.loss.to_bits()).collect::<Vec<_>>())
    };
    assert_eq!(run(false), run(true), "FnProvider results depend on the pipeline flag");
}

/// Crash-mid-checkpoint: a writer that died after `write()` but before
/// `rename()` leaves `<name>.<pid>.tmp` garbage. A fresh session must
/// sweep it, load the last *complete* checkpoint, and leave no `.tmp`
/// residue behind.
#[test]
fn truncated_checkpoint_write_is_swept_and_old_checkpoint_loads() {
    let spec = OptSpec::parse("tridiag-sonew").unwrap();
    let dir = std::env::temp_dir().join("sonew_pipeline_crash");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("run.ck");

    // a run that reached the step-8 checkpoint boundary...
    let mut straight = fresh_ae_session(&spec, true, Some(path.clone()), None);
    let m_straight = straight.run().unwrap();

    // ...then a later writer crashed mid-write: truncated bytes under a
    // temp name whose pid can no longer be alive (u32::MAX)
    let stale = dir.join(format!("run.ck.{}.tmp", u32::MAX));
    std::fs::write(&stale, b"SONEWCK2\x00trunc").unwrap();

    // fresh process: construction sweeps the stale temp, resume loads
    // the complete checkpoint
    let mut resumed = fresh_ae_session(&spec, true, Some(path.clone()), Some(path.clone()));
    assert!(!stale.exists(), "stale checkpoint temp file survived the sweep");
    assert_eq!(resumed.step, STEPS - STEPS % CK_EVERY);
    let m_resumed = resumed.run().unwrap();

    // post-resume trajectory matches the uninterrupted run bitwise
    let boundary = STEPS - STEPS % CK_EVERY;
    let tail: Vec<_> = m_straight.points.iter().filter(|p| p.step >= boundary).collect();
    assert_eq!(m_resumed.points.len(), tail.len());
    for (a, b) in m_resumed.points.iter().zip(tail) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "diverged at step {}", a.step);
    }
    assert_eq!(bits(&resumed.params), bits(&straight.params));

    // no temp residue of any kind left in the checkpoint directory
    let residue: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".tmp"))
        .collect();
    assert!(residue.is_empty(), "temp files left behind: {residue:?}");

    std::fs::remove_dir_all(dir).ok();
}

/// Satellite: resuming from a path that does not exist fails at session
/// construction with an error naming the missing file.
#[test]
fn resume_from_missing_file_names_the_path() {
    let spec = OptSpec::parse("adam").unwrap();
    let bogus = std::env::temp_dir().join("sonew_pipeline_nope").join("never-written.ck");
    let mlp = Mlp::new(&[49, 24, 12, 24, 49]);
    let mut rng = Rng::new(7);
    let params = mlp.init(&mut rng);
    let opt = spec
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &HyperParams::default())
        .unwrap();
    let provider = NativeAeProvider::new(mlp.clone(), SynthImages::new(5), 8);
    let err = TrainSession::new(
        spec.clone(),
        opt,
        params,
        provider,
        SessionConfig { resume_from: Some(bogus.clone()), ..Default::default() },
    )
    .err()
    .expect("constructing a session over a missing checkpoint must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("no such checkpoint"), "{msg}");
    assert!(msg.contains("never-written.ck"), "error does not name the path: {msg}");
}
