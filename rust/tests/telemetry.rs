//! Telemetry determinism contract (ISSUE 10): tracing and the metrics
//! registry observe the process — they never alter it. Every
//! deterministic surface (training checkpoints, loss trajectories,
//! sweep CSVs, serve fingerprints) must be bitwise identical with
//! tracing enabled and disabled, at any `SONEW_THREADS` (CI runs this
//! suite at 1 and 4). Also covered: the exported trace is schema-valid
//! JSONL carrying spans from every instrumented subsystem, and the
//! `Metrics` stage fields equal the recorded span durations to the
//! nanosecond (both sides of `telemetry::timed` share one clock pair).
//!
//! Tracing state is process-global, so every test here serializes on
//! one mutex and leaves tracing disabled with the rings drained.

use std::sync::{Mutex, MutexGuard};

use sonew::comm::{Communicator, LocalComm};
use sonew::coordinator::sweep::SearchSpace;
use sonew::coordinator::trainer::NativeAeProvider;
use sonew::coordinator::{
    evaluate_shard_outcomes, result_from_outcomes, Schedule, SessionConfig, SweepScheduler,
    TrainConfig, TrainSession, Trial,
};
use sonew::data::requests::SynthRequests;
use sonew::data::SynthImages;
use sonew::models::Mlp;
use sonew::optim::{HyperParams, OptSpec};
use sonew::serving::{replay, ModelStore, StoreConfig};
use sonew::telemetry;
use sonew::util::Rng;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests (global tracing state) and guarantee a clean slate:
/// tracing off, rings empty.
fn exclusive() -> MutexGuard<'static, ()> {
    let g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    telemetry::set_enabled(false);
    let _ = telemetry::trace::drain();
    g
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// One checkpointed AE training run; returns every deterministic byte
/// it produces: loss trajectory bits, final param bits, checkpoint
/// file bytes, and the stage summary line.
fn run_ae(tag: &str) -> (Vec<u32>, Vec<u32>, Vec<u8>, String) {
    let spec = OptSpec::parse("tridiag-sonew").unwrap();
    let dir = std::env::temp_dir().join(format!("sonew_telemetry_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("run.ck");
    let mlp = Mlp::new(&[49, 24, 12, 24, 49]);
    let mut rng = Rng::new(7);
    let params = mlp.init(&mut rng);
    let opt = spec
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &HyperParams::default())
        .unwrap();
    let provider = NativeAeProvider::new(mlp.clone(), SynthImages::new(5), 8);
    let mut s = TrainSession::new(
        spec.clone(),
        opt,
        params,
        provider,
        SessionConfig {
            train: TrainConfig {
                steps: 8,
                schedule: Schedule::Constant { lr: 2e-3 },
                log_every: 1,
                ..Default::default()
            },
            checkpoint_every: 4,
            checkpoint_path: Some(path.clone()),
            resume_from: None,
            pipeline: false,
            ..Default::default()
        },
    )
    .unwrap();
    let m = s.run().unwrap();
    let ck = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    (
        m.points.iter().map(|p| p.loss.to_bits()).collect(),
        bits(&s.params),
        ck,
        // the summary *format* must not change with tracing; its timing
        // values are wall-clock and are not compared across runs
        m.stage_summary(),
    )
}

#[test]
fn training_bytes_are_identical_with_tracing_on_and_off() {
    let _g = exclusive();
    let off = run_ae("off");
    telemetry::set_enabled(true);
    let on = run_ae("on");
    telemetry::set_enabled(false);
    let _ = telemetry::trace::drain();
    assert_eq!(off.0, on.0, "loss trajectory changed under --trace");
    assert_eq!(off.1, on.1, "final params changed under --trace");
    assert_eq!(off.2, on.2, "checkpoint bytes changed under --trace");
    for s in [&off.3, &on.3] {
        assert!(s.starts_with("stages: data-prep "), "{s}");
    }
}

#[test]
fn sweep_csv_is_identical_with_tracing_on_and_off() {
    let _g = exclusive();
    let space = SearchSpace::default();
    let base = HyperParams::default();
    let spec = OptSpec::parse("adam").unwrap();
    // pure-function objective: the CSV is a deterministic function of
    // (seed, trials), so any tracing influence would show immediately
    let objective = |t: &Trial| (t.lr.ln() - (3e-4f32).ln()).abs();
    let run = || {
        let threaded = SweepScheduler::new(3)
            .run(&spec, &space, &base, 24, 11, objective)
            .unwrap()
            .to_csv();
        // the multi-process hub path: shard outcomes merged rank-ordered
        let shards: Vec<_> = (0..2)
            .map(|r| {
                evaluate_shard_outcomes(&spec, &space, &base, 24, r, 2, 11, &mut { objective })
            })
            .collect();
        let hub = result_from_outcomes(&spec, &space, &base, 11, &shards).unwrap().to_csv();
        (threaded, hub)
    };
    let off = run();
    telemetry::set_enabled(true);
    let on = run();
    telemetry::set_enabled(false);
    let _ = telemetry::trace::drain();
    assert_eq!(off, on, "sweep CSV changed under --trace");
    assert_eq!(off.0, off.1, "threaded and hub sweeps disagree");
}

#[test]
fn serve_fingerprints_are_identical_with_tracing_on_and_off() {
    let _g = exclusive();
    let log = SynthRequests::new(13, 5, 32, 4).take(160);
    let run = || -> Vec<String> {
        let cfg = StoreConfig {
            dir: None,
            dim: 32,
            lr: 0.05,
            spec: OptSpec::parse("tridiag-sonew").unwrap(),
            base: HyperParams { eps: 1.0, ..Default::default() },
            checkpoint_every: 0,
        };
        let mut store = ModelStore::open(cfg, 3).unwrap();
        let report = replay(&mut store, &log, 40).unwrap();
        // the exact `[pv]` lines `sonew serve` emits, built through the
        // same fingerprint helper
        let mut lines: Vec<String> = report
            .curve
            .iter()
            .map(|p| {
                telemetry::fingerprint_line(
                    "pv",
                    format_args!(
                        "seen={} loss={:.6} acc={:.6}",
                        p.seen, p.mean_loss, p.accuracy
                    ),
                )
            })
            .collect();
        for id in store.model_ids() {
            let m = store.model(&id).unwrap();
            let mut bytes = Vec::with_capacity(4 * m.params().len());
            for w in m.params() {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
            lines.push(telemetry::fingerprint_line(
                "pv",
                format_args!(
                    "model {id} updates={} params=0x{:016x}",
                    m.updates(),
                    sonew::data::requests::fnv1a64(&bytes)
                ),
            ));
        }
        lines
    };
    let off = run();
    telemetry::set_enabled(true);
    let on = run();
    telemetry::set_enabled(false);
    let _ = telemetry::trace::drain();
    assert_eq!(off, on, "[pv] fingerprint lines changed under --trace");
    assert!(off.iter().all(|l| l.starts_with("[pv] ")), "{off:?}");
}

#[test]
fn exported_trace_is_schema_valid_and_covers_every_subsystem() {
    let _g = exclusive();
    telemetry::set_enabled(true);
    // trainer + executor + checkpoint spans
    let _ = run_ae("trace");
    // comm spans (LocalComm instruments the same span names the
    // TCP/thread communicators do)
    let comm = LocalComm;
    let mut buf = vec![1.0f32, 2.0];
    comm.all_reduce_sum(&mut buf).unwrap();
    comm.barrier().unwrap();
    // serving spans
    let cfg = StoreConfig {
        dir: None,
        dim: 16,
        lr: 0.05,
        spec: OptSpec::parse("adam").unwrap(),
        base: HyperParams { eps: 1.0, ..Default::default() },
        checkpoint_every: 0,
    };
    let mut store = ModelStore::open(cfg, 2).unwrap();
    let log = SynthRequests::new(3, 3, 16, 4).take(40);
    replay(&mut store, &log, 20).unwrap();
    telemetry::set_enabled(false);

    let dir = std::env::temp_dir().join(format!("sonew_telemetry_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.jsonl");
    telemetry::write_trace(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    // the aggregator consumes the same file; a missing path is an error
    telemetry::report::run(&path, true).unwrap();
    telemetry::report::run(&path.with_file_name("gone"), true).unwrap_err();
    std::fs::remove_dir_all(&dir).ok();

    let mut span_names = std::collections::BTreeSet::new();
    for line in text.lines() {
        if let telemetry::report::Line::Span { name, .. } =
            telemetry::report::validate_line(line).unwrap()
        {
            span_names.insert(name);
        }
    }
    for want in [
        "train.data_prep",
        "train.fwd_bwd",
        "train.opt_step",
        "train.ckpt",
        "ckpt.write",
        "exec.scope",
        "comm.all_reduce",
        "comm.barrier",
        "serve.shard",
        "serve.update",
    ] {
        assert!(span_names.contains(want), "trace is missing {want} spans: {span_names:?}");
    }
}

#[test]
fn report_aggregates_a_written_trace() {
    let _g = exclusive();
    telemetry::set_enabled(true);
    {
        let _s = sonew::span!("train.opt_step");
    }
    {
        let _s = sonew::span!("serve.shard");
    }
    telemetry::set_enabled(false);
    let dir = std::env::temp_dir().join(format!("sonew_telemetry_report_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("r.jsonl");
    telemetry::write_trace(&path).unwrap();
    telemetry::report::run(&path, true).unwrap();
    telemetry::report::run(&path, false).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn metrics_stage_fields_equal_span_durations_to_the_nanosecond() {
    let _g = exclusive();
    telemetry::set_enabled(true);
    // ephemeral, no checkpoint: the sync path times prepare/consume/step
    // on the training thread via telemetry::timed, which feeds the same
    // Duration into the Metrics field and the span ring
    let mlp = Mlp::new(&[49, 16, 49]);
    let mut rng = Rng::new(3);
    let params = mlp.init(&mut rng);
    let opt = OptSpec::parse("adam")
        .unwrap()
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &HyperParams::default())
        .unwrap();
    let provider = NativeAeProvider::new(mlp.clone(), SynthImages::new(2), 8);
    let mut s = TrainSession::ephemeral(
        opt,
        params,
        provider,
        TrainConfig { steps: 5, schedule: Schedule::Constant { lr: 1e-3 }, ..Default::default() },
    );
    let m = s.run().unwrap();
    let (events, dropped) = telemetry::trace::drain();
    telemetry::set_enabled(false);
    assert_eq!(dropped, 0, "ring overflow in a 5-step run");
    let sum = |name: &str| -> u128 {
        events.iter().filter(|e| e.name == name).map(|e| e.dur_ns as u128).sum()
    };
    assert_eq!(sum("train.data_prep"), m.data_time.as_nanos());
    assert_eq!(sum("train.fwd_bwd"), m.grad_time.as_nanos());
    assert_eq!(sum("train.opt_step"), m.opt_time.as_nanos());
}

#[test]
fn committed_bench_baseline_is_schema_valid() {
    // the baseline trajectory point checked into the repo must always
    // parse under the same validator CI applies to fresh bench output
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_baseline.json");
    telemetry::sink::validate_file(&path).unwrap();
}
