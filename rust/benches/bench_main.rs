//! Benchmark harness (criterion is unavailable offline — custom
//! median-of-k timing via util::timer::bench).
//!
//! Sections map to the paper's evaluation:
//!   [t1]    per-step optimizer cost vs layer size (Table 1)
//!   [step]  full-AE per-step wall time share, tridiag vs Adam (the
//!           "~3% slower per step" claim, §1)
//!   [kernel] native SONew kernel throughput (GB/s of parameter state)
//!   [backend] grads-program dispatch overhead through the Backend trait
//!   [lm]    native transformer lm_grads step cost (Figure-3 model), so
//!           the LM forward/backward is tracked alongside the tridiag
//!           kernel it feeds
//!   [hlo]   PJRT execution overhead of the AOT artifacts (xla feature +
//!           artifacts present; skipped otherwise)
//!
//!     cargo bench            # all sections
//!     cargo bench -- t1      # one section

use sonew::models::{LmConfig, Transformer};
use sonew::optim::{HyperParams, OptSpec};
use sonew::runtime::{Backend, HostTensor, NativeBackend};
use sonew::sonew::{BandedState, LambdaMode, TridiagState};
use sonew::util::timer::bench;
use sonew::util::{Precision, Rng};

fn main() {
    let filter = std::env::args().nth(1).unwrap_or_default();
    let run = |name: &str| filter.is_empty() || name.contains(&filter) || filter == "--bench";

    if run("t1") {
        println!("== [t1] per-step optimizer cost vs layer size (Table 1) ==");
        sonew::tables::t1_complexity::run(&[32, 64, 128, 256], 20).unwrap();
    }

    if run("kernel") {
        println!("== [kernel] native SONew kernel throughput ==");
        for n in [1 << 16, 1 << 20, 1 << 22] {
            let mut rng = Rng::new(1);
            let g = rng.normal_vec(n);
            let mut u = vec![0.0f32; n];
            let mut st = TridiagState::new(n, None);
            let r = bench(&format!("tridiag step n={n}"), 10, 5, |k| {
                for _ in 0..k {
                    st.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
                }
            });
            // streams: read hd,ho,g + write hd,ho,u = 6 x 4B x n
            let gbs = 24.0 * n as f64 / r.per_iter_ns();
            println!("{}   {:.2} GB/s", r.report(), gbs);

            let mut bs = BandedState::new(n, 4, None);
            let r = bench(&format!("band-4  step n={n}"), 4, 3, |k| {
                for _ in 0..k {
                    bs.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
                }
            });
            println!("{}", r.report());
            if n >= 1 << 22 {
                break; // band-4 at 4M is ~seconds; one size is enough
            }
        }
    }

    if run("step") {
        println!("== [step] full-AE optimizer step: tridiag-SONew vs Adam ==");
        let mlp = sonew::models::Mlp::autoencoder();
        let n = mlp.total;
        let mut rng = Rng::new(2);
        let g = rng.normal_vec(n);
        for spec in ["adam", "diag-sonew", "tridiag-sonew", "band-sonew"] {
            let hp = HyperParams { grafting: false, beta1: 0.0, ..Default::default() };
            let mut opt = OptSpec::parse(spec)
                .unwrap()
                .build(n, &mlp.blocks(), &mlp.mat_blocks(), &hp)
                .unwrap();
            let mut params = vec![0.01f32; n];
            let r = bench(&format!("{} step n={n}", opt.name()), 5, 5, |k| {
                for _ in 0..k {
                    opt.step(&mut params, &g, 1e-3);
                }
            });
            println!("{}", r.report());
        }
    }

    if run("backend") {
        println!("== [backend] grads dispatch through the Backend trait ==");
        let backend = NativeBackend::new();
        let mlp = sonew::models::Mlp::autoencoder_small();
        let mut rng = Rng::new(4);
        let params = mlp.init(&mut rng);
        let x = rng.uniform_vec(64 * mlp.dims[0], 0.0, 1.0);
        let r = bench("native ae_small grads b64", 5, 5, |k| {
            for _ in 0..k {
                backend
                    .loss_and_grad(
                        "ae_small_grads_b64",
                        &params,
                        vec![HostTensor::F32(x.clone())],
                    )
                    .unwrap();
            }
        });
        println!("{}", r.report());
    }

    if run("lm") {
        println!("== [lm] native transformer lm_grads (Figure-3 model) ==");
        let backend = NativeBackend::new();
        // scaled-down config: layer-stack + dispatch overhead
        let small = Transformer::new(LmConfig::small());
        let params = small.init(5);
        let mut corpus = sonew::data::LmCorpus::new(small.cfg.vocab, 6);
        let (toks, tgts) = corpus.batch(4, small.cfg.seq);
        let r = bench("native lm_small grads b4", 5, 5, |k| {
            for _ in 0..k {
                backend
                    .loss_and_grad(
                        "lm_small_grads",
                        &params,
                        vec![HostTensor::I32(toks.clone()), HostTensor::I32(tgts.clone())],
                    )
                    .unwrap();
            }
        });
        println!("{}", r.report());
        // the Figure-3 model itself: the per-step grads cost that the
        // tridiag-SONew optimizer step rides on top of
        let full = Transformer::new(LmConfig::figure3());
        let params = full.init(7);
        let mut corpus = sonew::data::LmCorpus::new(full.cfg.vocab, 8);
        let (toks, tgts) = corpus.batch(2, full.cfg.seq);
        let r = bench(
            &format!("native lm grads b2 s{} n={}", full.cfg.seq, full.total),
            3,
            2,
            |k| {
                for _ in 0..k {
                    backend
                        .loss_and_grad(
                            "lm_grads",
                            &params,
                            vec![HostTensor::I32(toks.clone()), HostTensor::I32(tgts.clone())],
                        )
                        .unwrap();
                }
            },
        );
        println!("{}", r.report());
    }

    if run("hlo") {
        'hlo: {
        let dir = sonew::runtime::default_artifacts_dir();
        let backend = match sonew::runtime::open_backend(&dir) {
            Ok(b) => b,
            Err(e) => {
                println!("[hlo] skipped (failed to open artifacts backend: {e:#})");
                break 'hlo;
            }
        };
        if let Some(man) = backend.manifest() {
            println!("== [hlo] PJRT artifact execution ==");
            if let Ok(spec) = man.artifact("sonew_tridiag_ae_small") {
                let n = spec.inputs[0].elements();
                let hd = vec![1.0f32; n];
                let ho = vec![0.0f32; n];
                let mut rng = Rng::new(3);
                let g = rng.normal_vec(n);
                let tids = man.layout("ae_small").unwrap().tensor_ids();
                let r = bench(&format!("hlo sonew_tridiag n={n}"), 5, 5, |k| {
                    for _ in 0..k {
                        backend
                            .exec("sonew_tridiag_ae_small", &[
                                HostTensor::F32(hd.clone()),
                                HostTensor::F32(ho.clone()),
                                HostTensor::F32(g.clone()),
                                HostTensor::F32(tids.clone()),
                            ])
                            .unwrap();
                    }
                });
                println!("{}", r.report());
            }
            if let Ok(spec) = man.artifact("ae_small_grads_b64") {
                let np = spec.inputs[0].elements();
                let bx = spec.inputs[1].elements();
                let params = vec![0.01f32; np];
                let x = vec![0.5f32; bx];
                let r = bench("hlo ae_small grads b64", 5, 5, |k| {
                    for _ in 0..k {
                        backend
                            .loss_and_grad(
                                "ae_small_grads_b64",
                                &params,
                                vec![HostTensor::F32(x.clone())],
                            )
                            .unwrap();
                    }
                });
                println!("{}", r.report());
            }
        } else {
            println!(
                "[hlo] skipped ({} backend; build with --features xla and run \
                 `make artifacts`)",
                backend.name()
            );
        }
        }
    }
    println!("bench done");
}
