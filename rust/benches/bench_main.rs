//! Benchmark harness (criterion is unavailable offline — custom
//! median-of-k timing via telemetry::timing::bench).
//!
//! Sections map to the paper's evaluation:
//!   [exec]  persistent-executor fan-out dispatch vs a per-call
//!           scoped-thread spawn (the fixed cost `run_chunked` pays on
//!           every parallel kernel call)
//!   [gemm]  blocked GEMM engine vs the seed i-k-j kernel (speedup is
//!           the headline hot-path number)
//!   [t1]    per-step optimizer cost vs layer size (Table 1)
//!   [step]  full-AE per-step wall time share, tridiag vs Adam (the
//!           "~3% slower per step" claim, §1)
//!   [kernel] native SONew kernel throughput (GB/s of parameter state)
//!           plus the block-parallel multi-tensor scan vs pinned
//!           sequential
//!   [backend] grads-program dispatch overhead through the Backend trait
//!   [lm]    native transformer lm_grads step cost (Figure-3 model), so
//!           the LM forward/backward is tracked alongside the tridiag
//!           kernel it feeds
//!   [hlo]   PJRT execution overhead of the AOT artifacts (xla feature +
//!           artifacts present; skipped otherwise)
//!   [pipeline] staged TrainSession loop: overlapped (prefetch +
//!           background checkpoint writer) vs strictly synchronous step
//!           time on the LM workload, and the checkpoint-boundary stall
//!   [serve] online predict-then-update: per-request update latency
//!           (p50/p99) and sharded replay throughput, tridiag-SONew vs
//!           sparse-ONS vs Adam on a synthetic request stream
//!   [comm]  communicator primitives: the fixed-shape tree-fold merge
//!           over gradient-sized contributions, and in-process
//!           `all_reduce_sum` latency at world 4 (the per-step cost a
//!           data-parallel session pays on top of the raw adds)
//!   [telemetry] observability overhead: span-record cost with tracing
//!           enabled vs disabled, and LM training step time with
//!           tracing on vs off (the "< 5% enabled, ~0 disabled"
//!           contract from the telemetry module docs)
//!
//!     cargo bench                # all sections
//!     cargo bench -- gemm        # one section
//!     cargo bench -- --smoke     # short CI-sized run
//!
//! Every run writes its numbers to a `BENCH_*.json` trajectory document
//! (`SONEW_BENCH_OUT` overrides the `BENCH_latest.json` default) so CI
//! can smoke-run the harness and archive per-commit perf history. The
//! document is built by `telemetry::sink::BenchRecorder` and emitted
//! through the `TelemetrySink` trait, so it also carries a snapshot of
//! the process metrics registry (`"telemetry"` section).

use sonew::linalg::{matmul_into, matmul_nt, matmul_tn, Mat};
use sonew::models::{LmConfig, Transformer};
use sonew::optim::{HyperParams, OptSpec};
use sonew::runtime::{Backend, HostTensor, NativeBackend};
use sonew::sonew::{BandedState, LambdaMode, TridiagState};
use sonew::telemetry::sink::{BenchRecorder, JsonFileSink, TelemetrySink};
use sonew::telemetry::timing::bench;
use sonew::util::{Precision, Rng};

/// The pre-engine kernel (PR 2-era `matmul_into`): i-k-j streaming
/// triple loop with the same row-chunk threading — the baseline the
/// blocked engine's speedup is measured against.
fn seed_matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let rows_kernel = |a_data: &[f32], b_data: &[f32], c_chunk: &mut [f32], lo: usize| {
        let rows = c_chunk.len() / n;
        for r in 0..rows {
            let i = lo + r;
            let arow = &a_data[i * k..(i + 1) * k];
            let crow = &mut c_chunk[r * n..(r + 1) * n];
            crow.iter_mut().for_each(|v| *v = 0.0);
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &b_data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    };
    let threads = sonew::linalg::hw_threads().min(m.max(1));
    if threads <= 1 {
        rows_kernel(&a.data, &b.data, &mut c.data, 0);
        return;
    }
    let chunk = m.div_ceil(threads);
    let a_data = &a.data;
    let b_data = &b.data;
    let rk = &rows_kernel;
    std::thread::scope(|s| {
        for (t, c_chunk) in c.data.chunks_mut(chunk * n).enumerate() {
            s.spawn(move || rk(a_data, b_data, c_chunk, t * chunk));
        }
    });
}

fn main() {
    let mut filter = String::new();
    let mut smoke = false;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--smoke" => smoke = true,
            "--bench" => {}
            other => filter = other.to_string(),
        }
    }
    let run = |name: &str| filter.is_empty() || name.contains(&filter);
    let mut rec = BenchRecorder::new();
    if smoke {
        println!("(smoke mode: reduced sizes and iteration counts)");
    }

    if run("exec") {
        println!("== [exec] persistent-pool fan-out vs per-call scoped spawn ==");
        let threads = sonew::linalg::hw_threads();
        let n_items = 64usize;
        let (iters, kk) = if smoke { (50, 3) } else { (400, 5) };
        let r_pool = bench("run_chunked 64 jobs (persistent pool)", iters, kk, |k| {
            for _ in 0..k {
                let items: Vec<usize> = (0..n_items).collect();
                sonew::util::par::run_chunked(items, threads, |i| {
                    std::hint::black_box(i);
                });
            }
        });
        println!("{}", r_pool.report());
        rec.add("exec", &r_pool);
        // the pre-Execution-API shape: spawn + join scoped threads on
        // every call, same contiguous grouping
        let r_spawn = bench("scoped spawn 64 jobs (per-call threads)", iters, kk, |k| {
            for _ in 0..k {
                let mut items: Vec<usize> = (0..n_items).collect();
                let per = n_items.div_ceil(threads);
                std::thread::scope(|s| {
                    while !items.is_empty() {
                        let take = per.min(items.len());
                        let group: Vec<usize> = items.drain(..take).collect();
                        s.spawn(move || {
                            for i in group {
                                std::hint::black_box(i);
                            }
                        });
                    }
                });
            }
        });
        println!("{}", r_spawn.report());
        rec.add("exec", &r_spawn);
        let sp = r_spawn.per_iter_ns() / r_pool.per_iter_ns();
        println!("    persistent-pool dispatch speedup vs per-call spawn: {sp:.2}x");
        rec.derive("exec_fanout_speedup_vs_spawn".to_string(), sp);
    }

    if run("gemm") {
        println!("== [gemm] blocked GEMM engine vs seed i-k-j kernel ==");
        let active = sonew::linalg::kernels::active();
        let feats = sonew::linalg::kernels::cpu_features();
        let avail: Vec<&str> =
            sonew::linalg::kernels::available().iter().map(|kk| kk.name).collect();
        sonew::telemetry::emit_fingerprint(
            "gemm",
            format_args!(
                "micro-kernel: {} (cpu: {feats}; available: {})",
                active.name,
                avail.join(",")
            ),
        );
        rec.note("kernel", active.name.to_string());
        rec.note("cpu_features", feats);
        rec.note("kernels_available", avail.join(","));
        let sizes: &[usize] = if smoke { &[128, 256] } else { &[256, 512] };
        let (iters, k) = if smoke { (4, 3) } else { (10, 5) };
        for &sz in sizes {
            let mut rng = Rng::new(1);
            let a = Mat::from_rows(sz, sz, rng.normal_vec(sz * sz));
            let b = Mat::from_rows(sz, sz, rng.normal_vec(sz * sz));
            let mut c = Mat::zeros(sz, sz);
            let r = bench(&format!("gemm {sz}x{sz}x{sz}"), iters, k, |kk| {
                for _ in 0..kk {
                    matmul_into(&a, &b, &mut c);
                }
            });
            let gflops = 2.0 * (sz as f64).powi(3) / r.per_iter_ns();
            println!("{}   {gflops:.2} GFLOP/s", r.report());
            rec.add("gemm", &r);
            let rs = bench(&format!("seed {sz}x{sz}x{sz}"), iters, k, |kk| {
                for _ in 0..kk {
                    seed_matmul_into(&a, &b, &mut c);
                }
            });
            println!("{}", rs.report());
            rec.add("gemm", &rs);
            let speedup = rs.per_iter_ns() / r.per_iter_ns();
            println!("    blocked engine speedup vs seed kernel: {speedup:.2}x");
            rec.derive(format!("gemm_{sz}_speedup_vs_seed"), speedup);
        }
        // the backward-path transpose variants at the largest size
        let sz = *sizes.last().unwrap();
        let mut rng = Rng::new(2);
        let a = Mat::from_rows(sz, sz, rng.normal_vec(sz * sz));
        let b = Mat::from_rows(sz, sz, rng.normal_vec(sz * sz));
        let r = bench(&format!("gemm_tn {sz}x{sz}x{sz}"), iters, k, |kk| {
            for _ in 0..kk {
                std::hint::black_box(matmul_tn(&a, &b));
            }
        });
        println!("{}", r.report());
        rec.add("gemm", &r);
        let r = bench(&format!("gemm_nt {sz}x{sz}x{sz}"), iters, k, |kk| {
            for _ in 0..kk {
                std::hint::black_box(matmul_nt(&a, &b));
            }
        });
        println!("{}", r.report());
        rec.add("gemm", &r);

        // every micro-kernel this CPU offers, pinned to the same thread
        // budget, so the trajectory isolates the dispatch layer's gain
        use sonew::linalg::{gemm_with, Trans};
        let threads = sonew::linalg::hw_threads();
        let mut c = Mat::zeros(sz, sz);
        let mut per_kernel: Vec<(&str, f64)> = Vec::new();
        for kern in sonew::linalg::kernels::available() {
            let r = bench(&format!("gemm {sz} kernel={}", kern.name), iters, k, |kk| {
                for _ in 0..kk {
                    gemm_with(
                        &a.data,
                        Trans::N,
                        &b.data,
                        Trans::N,
                        &mut c.data,
                        (sz, sz, sz),
                        threads,
                        kern,
                    );
                }
            });
            println!("{}", r.report());
            rec.add("gemm", &r);
            per_kernel.push((kern.name, r.per_iter_ns()));
        }
        if let Some(&(_, base)) = per_kernel.iter().find(|&&(nm, _)| nm == "portable") {
            for &(nm, ns) in &per_kernel {
                if nm != "portable" {
                    let sp = base / ns;
                    println!("    kernel {nm} speedup vs portable: {sp:.2}x");
                    rec.derive(format!("gemm_{sz}_{nm}_speedup_vs_portable"), sp);
                }
            }
        }
    }

    if run("t1") {
        println!("== [t1] per-step optimizer cost vs layer size (Table 1) ==");
        let (sizes, steps): (&[usize], u64) =
            if smoke { (&[32, 64], 5) } else { (&[32, 64, 128, 256], 20) };
        sonew::tables::t1_complexity::run(sizes, steps).unwrap();
    }

    if run("kernel") {
        println!("== [kernel] native SONew kernel throughput ==");
        let sizes: &[usize] = if smoke { &[1 << 16] } else { &[1 << 16, 1 << 20, 1 << 22] };
        for &n in sizes {
            let mut rng = Rng::new(1);
            let g = rng.normal_vec(n);
            let mut u = vec![0.0f32; n];
            let mut st = TridiagState::new(n, None);
            let r = bench(&format!("tridiag step n={n}"), 10, 5, |k| {
                for _ in 0..k {
                    st.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
                }
            });
            // streams: read hd,ho,g + write hd,ho,u = 6 x 4B x n
            let gbs = 24.0 * n as f64 / r.per_iter_ns();
            println!("{}   {:.2} GB/s", r.report(), gbs);
            rec.add("kernel", &r);

            let mut bs = BandedState::new(n, 4, None);
            let r = bench(&format!("band-4  step n={n}"), 4, 3, |k| {
                for _ in 0..k {
                    bs.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
                }
            });
            println!("{}", r.report());
            rec.add("kernel", &r);
            if n >= 1 << 22 {
                break; // band-4 at 4M is ~seconds; one size is enough
            }
        }

        // block-parallel multi-tensor scan vs pinned-sequential: the
        // per-tensor edge masks make tensor blocks independent, so the
        // solve scan fans out across them (bitwise-identically)
        let tensors = 16usize;
        let n = if smoke { 1 << 18 } else { 1 << 21 };
        let ids: Vec<f32> = (0..n).map(|j| (j * tensors / n) as f32).collect();
        let mut rng = Rng::new(9);
        let g = rng.normal_vec(n);
        let mut u = vec![0.0f32; n];
        let (iters, kk) = if smoke { (4, 3) } else { (10, 5) };
        let mut seq = TridiagState::new(n, Some(&ids));
        seq.parallel = false;
        let r_seq = bench(&format!("tridiag seq n={n} tensors={tensors}"), iters, kk, |k| {
            for _ in 0..k {
                seq.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
            }
        });
        println!("{}", r_seq.report());
        rec.add("kernel", &r_seq);
        let mut par = TridiagState::new(n, Some(&ids));
        let r_par = bench(&format!("tridiag par n={n} tensors={tensors}"), iters, kk, |k| {
            for _ in 0..k {
                par.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
            }
        });
        println!("{}", r_par.report());
        rec.add("kernel", &r_par);
        let sp = r_seq.per_iter_ns() / r_par.per_iter_ns();
        println!("    tridiag block-parallel speedup: {sp:.2}x");
        rec.derive(format!("tridiag_block_parallel_speedup_n{n}"), sp);

        let nb = if smoke { 1 << 16 } else { 1 << 19 };
        let ids: Vec<f32> = (0..nb).map(|j| (j * tensors / nb) as f32).collect();
        let g = rng.normal_vec(nb);
        let mut u = vec![0.0f32; nb];
        let (iters, kk) = if smoke { (2, 2) } else { (4, 3) };
        let mut seq = BandedState::new(nb, 4, Some(&ids));
        seq.parallel = false;
        let r_seq = bench(&format!("band-4  seq n={nb} tensors={tensors}"), iters, kk, |k| {
            for _ in 0..k {
                seq.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
            }
        });
        println!("{}", r_seq.report());
        rec.add("kernel", &r_seq);
        let mut par = BandedState::new(nb, 4, Some(&ids));
        let r_par = bench(&format!("band-4  par n={nb} tensors={tensors}"), iters, kk, |k| {
            for _ in 0..k {
                par.step(&g, &mut u, LambdaMode::Ema(0.95), 1e-6, 0.0, Precision::F32);
            }
        });
        println!("{}", r_par.report());
        rec.add("kernel", &r_par);
        let sp = r_seq.per_iter_ns() / r_par.per_iter_ns();
        println!("    banded block-parallel speedup: {sp:.2}x");
        rec.derive(format!("banded_block_parallel_speedup_n{nb}"), sp);
    }

    if run("step") {
        println!("== [step] full-AE optimizer step: tridiag-SONew vs Adam ==");
        let mlp = if smoke {
            sonew::models::Mlp::autoencoder_small()
        } else {
            sonew::models::Mlp::autoencoder()
        };
        let n = mlp.total;
        let mut rng = Rng::new(2);
        let g = rng.normal_vec(n);
        let (iters, kk) = if smoke { (2, 2) } else { (5, 5) };
        for spec in ["adam", "diag-sonew", "tridiag-sonew", "band-sonew"] {
            let hp = HyperParams { grafting: false, beta1: 0.0, ..Default::default() };
            let mut opt = OptSpec::parse(spec)
                .unwrap()
                .build(n, &mlp.blocks(), &mlp.mat_blocks(), &hp)
                .unwrap();
            let mut params = vec![0.01f32; n];
            let r = bench(&format!("{} step n={n}", opt.name()), iters, kk, |k| {
                for _ in 0..k {
                    opt.step(&mut params, &g, 1e-3);
                }
            });
            println!("{}", r.report());
            rec.add("step", &r);
        }
    }

    if run("backend") {
        println!("== [backend] grads dispatch through the Backend trait ==");
        let backend = NativeBackend::new();
        let mlp = sonew::models::Mlp::autoencoder_small();
        let mut rng = Rng::new(4);
        let params = mlp.init(&mut rng);
        let x = rng.uniform_vec(64 * mlp.dims[0], 0.0, 1.0);
        let (iters, kk) = if smoke { (2, 2) } else { (5, 5) };
        let r = bench("native ae_small grads b64", iters, kk, |k| {
            for _ in 0..k {
                backend
                    .loss_and_grad(
                        "ae_small_grads_b64",
                        &params,
                        vec![HostTensor::F32(x.clone())],
                    )
                    .unwrap();
            }
        });
        println!("{}", r.report());
        rec.add("backend", &r);
    }

    if run("lm") {
        println!("== [lm] native transformer lm_grads (Figure-3 model) ==");
        let backend = NativeBackend::new();
        // scaled-down config: layer-stack + dispatch overhead
        let small = Transformer::new(LmConfig::small());
        let params = small.init(5);
        let mut corpus = sonew::data::LmCorpus::new(small.cfg.vocab, 6);
        let (toks, tgts) = corpus.batch(4, small.cfg.seq);
        let (iters, kk) = if smoke { (2, 2) } else { (5, 5) };
        let r = bench("native lm_small grads b4", iters, kk, |k| {
            for _ in 0..k {
                backend
                    .loss_and_grad(
                        "lm_small_grads",
                        &params,
                        vec![HostTensor::I32(toks.clone()), HostTensor::I32(tgts.clone())],
                    )
                    .unwrap();
            }
        });
        println!("{}", r.report());
        rec.add("lm", &r);
        if !smoke {
            // the Figure-3 model itself: the per-step grads cost that the
            // tridiag-SONew optimizer step rides on top of
            let full = Transformer::new(LmConfig::figure3());
            let params = full.init(7);
            let mut corpus = sonew::data::LmCorpus::new(full.cfg.vocab, 8);
            let (toks, tgts) = corpus.batch(2, full.cfg.seq);
            let r = bench(
                &format!("native lm grads b2 s{} n={}", full.cfg.seq, full.total),
                3,
                2,
                |k| {
                    for _ in 0..k {
                        backend
                            .loss_and_grad(
                                "lm_grads",
                                &params,
                                vec![
                                    HostTensor::I32(toks.clone()),
                                    HostTensor::I32(tgts.clone()),
                                ],
                            )
                            .unwrap();
                    }
                },
            );
            println!("{}", r.report());
            rec.add("lm", &r);
        }
    }

    if run("hlo") {
        'hlo: {
        let dir = sonew::runtime::default_artifacts_dir();
        let backend = match sonew::runtime::open_backend(&dir) {
            Ok(b) => b,
            Err(e) => {
                println!("[hlo] skipped (failed to open artifacts backend: {e:#})");
                break 'hlo;
            }
        };
        if let Some(man) = backend.manifest() {
            println!("== [hlo] PJRT artifact execution ==");
            if let Ok(spec) = man.artifact("sonew_tridiag_ae_small") {
                let n = spec.inputs[0].elements();
                let hd = vec![1.0f32; n];
                let ho = vec![0.0f32; n];
                let mut rng = Rng::new(3);
                let g = rng.normal_vec(n);
                let tids = man.layout("ae_small").unwrap().tensor_ids();
                let r = bench(&format!("hlo sonew_tridiag n={n}"), 5, 5, |k| {
                    for _ in 0..k {
                        backend
                            .exec("sonew_tridiag_ae_small", &[
                                HostTensor::F32(hd.clone()),
                                HostTensor::F32(ho.clone()),
                                HostTensor::F32(g.clone()),
                                HostTensor::F32(tids.clone()),
                            ])
                            .unwrap();
                    }
                });
                println!("{}", r.report());
                rec.add("hlo", &r);
            }
            if let Ok(spec) = man.artifact("ae_small_grads_b64") {
                let np = spec.inputs[0].elements();
                let bx = spec.inputs[1].elements();
                let params = vec![0.01f32; np];
                let x = vec![0.5f32; bx];
                let r = bench("hlo ae_small grads b64", 5, 5, |k| {
                    for _ in 0..k {
                        backend
                            .loss_and_grad(
                                "ae_small_grads_b64",
                                &params,
                                vec![HostTensor::F32(x.clone())],
                            )
                            .unwrap();
                    }
                });
                println!("{}", r.report());
                rec.add("hlo", &r);
            }
        } else {
            println!(
                "[hlo] skipped ({} backend; build with --features xla and run \
                 `make artifacts`)",
                backend.name()
            );
        }
        }
    }

    if run("pipeline") {
        println!("== [pipeline] staged train loop: overlapped vs synchronous ==");
        // the LM workload from the [lm] section driven through the one
        // training engine, pipeline on vs off — results are bitwise
        // identical (tests/pipeline.rs), so the only difference is time.
        // Sessions are stateful, so each mode is timed over one run
        // rather than through bench()'s repeat harness.
        let steps: u64 = if smoke { 8 } else { 40 };
        let ck_every: u64 = 4;
        let dir = std::env::temp_dir().join("sonew_bench_pipeline");
        std::fs::remove_dir_all(&dir).ok();
        let time_run = |pipeline: bool, checkpoint: bool| -> (f64, f64) {
            let model = Transformer::new(LmConfig::small());
            let params = model.init(5);
            let blocks = sonew::optim::blocks_of(&model.layout);
            let mats = sonew::optim::mat_blocks_of(&model.layout);
            let spec = OptSpec::parse("adam").unwrap();
            let opt = spec
                .build(model.total, &blocks, &mats, &HyperParams::default())
                .unwrap();
            let provider = sonew::coordinator::trainer::BackendLmProvider::new(
                Box::new(NativeBackend::new()),
                "lm_small_grads",
                sonew::data::LmCorpus::new(model.cfg.vocab, 6),
                4,
                model.cfg.seq,
            );
            let cfg = sonew::coordinator::SessionConfig {
                train: sonew::coordinator::TrainConfig {
                    steps,
                    schedule: sonew::coordinator::Schedule::Constant { lr: 1e-3 },
                    ..Default::default()
                },
                checkpoint_every: if checkpoint { ck_every } else { 0 },
                checkpoint_path: checkpoint.then(|| dir.join(format!("bench_{pipeline}.ck"))),
                resume_from: None,
                pipeline,
                ..Default::default()
            };
            let mut s = sonew::coordinator::TrainSession::new(spec, opt, params, provider, cfg)
                .unwrap();
            let t = std::time::Instant::now();
            let m = s.run().unwrap();
            let step_us = t.elapsed().as_nanos() as f64 / 1000.0 / steps as f64;
            let boundaries = (steps / ck_every).max(1);
            let stall_us = m.ckpt_time.as_nanos() as f64 / 1000.0 / boundaries as f64;
            (step_us, stall_us)
        };
        // warm the executor + backend caches so neither mode pays
        // first-touch costs
        let _ = time_run(true, false);
        let (sync_us, _) = time_run(false, false);
        let (pipe_us, _) = time_run(true, false);
        println!("    lm step synchronous : {sync_us:.1} us/step");
        println!("    lm step overlapped  : {pipe_us:.1} us/step");
        let sp = sync_us / pipe_us;
        println!("    prefetch overlap speedup: {sp:.2}x");
        rec.derive("pipeline_lm_step_us_sync".to_string(), sync_us);
        rec.derive("pipeline_lm_step_us_overlapped".to_string(), pipe_us);
        rec.derive("pipeline_overlap_speedup".to_string(), sp);
        let (_, stall_sync) = time_run(false, true);
        let (_, stall_pipe) = time_run(true, true);
        println!("    checkpoint stall synchronous: {stall_sync:.1} us/boundary");
        println!("    checkpoint stall overlapped : {stall_pipe:.1} us/boundary");
        rec.derive("pipeline_ckpt_stall_us_sync".to_string(), stall_sync);
        rec.derive("pipeline_ckpt_stall_us_overlapped".to_string(), stall_pipe);
        std::fs::remove_dir_all(&dir).ok();
    }

    if run("serve") {
        println!("== [serve] online predict-then-update: latency + throughput ==");
        use sonew::serving::{replay, ModelStore, StoreConfig};
        let (requests, dim, nnz) = if smoke { (400usize, 256, 8) } else { (3000, 512, 16) };
        for spec in ["sparse-ons", "tridiag-sonew", "adam"] {
            let mk_cfg = || StoreConfig {
                dir: None,
                dim,
                lr: if spec == "sparse-ons" { 1.0 } else { 0.05 },
                spec: OptSpec::parse(spec).unwrap(),
                base: HyperParams { eps: 1.0, ..Default::default() },
                checkpoint_every: 0,
            };
            let log = sonew::data::SynthRequests::new(31, 8, dim, nnz).take(requests);
            // per-request latency on one shard, sequentially — measures
            // the predict + update path itself, no queueing noise
            let mut store = ModelStore::open(mk_cfg(), 1).unwrap();
            let mut lat_ns: Vec<f64> = Vec::with_capacity(requests);
            for req in &log {
                let t = std::time::Instant::now();
                store.process(&req.model, &req.feats, req.label).unwrap();
                lat_ns.push(t.elapsed().as_nanos() as f64);
            }
            lat_ns.sort_by(|a, b| a.total_cmp(b));
            let p50 = lat_ns[lat_ns.len() / 2] / 1000.0;
            let p99 = lat_ns[(lat_ns.len() * 99 / 100).min(lat_ns.len() - 1)] / 1000.0;
            // end-to-end throughput through the sharded batcher
            let mut sharded = ModelStore::open(mk_cfg(), 4).unwrap();
            let t = std::time::Instant::now();
            replay(&mut sharded, &log, requests).unwrap();
            let rps = requests as f64 / t.elapsed().as_secs_f64().max(1e-9);
            println!(
                "    {spec:<14} update p50 {p50:>7.1} us  p99 {p99:>7.1} us  \
                 replay {rps:>8.0} req/s (4 shards)"
            );
            rec.derive(format!("serve_p50_us_{spec}"), p50);
            rec.derive(format!("serve_p99_us_{spec}"), p99);
            rec.derive(format!("serve_rps_{spec}"), rps);
        }
    }

    if run("comm") {
        println!("== [comm] communicator primitives ==");
        let n = if smoke { 1 << 16 } else { 1 << 20 };
        let leaves = 8usize;
        let (iters, kk) = if smoke { (4, 3) } else { (10, 5) };
        let mut rng = Rng::new(11);
        let contribs: Vec<Vec<f32>> = (0..leaves).map(|_| rng.normal_vec(n)).collect();
        let r = bench(&format!("tree_fold {leaves} x n={n}"), iters, kk, |k| {
            for _ in 0..k {
                let v = sonew::comm::tree_fold(contribs.clone(), |mut a, b| {
                    sonew::comm::add_assign(&mut a, &b);
                    a
                });
                std::hint::black_box(v);
            }
        });
        println!("{}", r.report());
        rec.add("comm", &r);
        // in-process all-reduce at world 4: rendezvous + rank-ordered
        // fold. The post-reduce 1/world rescale mirrors the data-parallel
        // step (and keeps the buffer values fixed across ops, since every
        // rank contributes the same vector).
        let world = 4usize;
        let ops: u64 = if smoke { 20 } else { 100 };
        let base = rng.normal_vec(n);
        let us = sonew::comm::thread::run_world(world, |comm| {
            let mut buf = base.clone();
            let inv = 1.0 / world as f32;
            let t = std::time::Instant::now();
            for _ in 0..ops {
                comm.all_reduce_sum(&mut buf).unwrap();
                for v in &mut buf {
                    *v *= inv;
                }
            }
            t.elapsed().as_nanos() as f64 / 1000.0 / ops as f64
        });
        println!("    all_reduce_sum world={world} n={n}: {:.1} us/op (rank 0)", us[0]);
        rec.derive(format!("comm_allreduce_us_world{world}_n{n}"), us[0]);
    }

    if run("telemetry") {
        println!("== [telemetry] observability overhead ==");
        use sonew::telemetry;
        // raw span cost on a hot path: disabled is one relaxed atomic
        // load; enabled pays two clock reads plus a ring push
        let (iters, kk): (u64, usize) = if smoke { (10_000, 3) } else { (100_000, 5) };
        telemetry::set_enabled(false);
        let r_off = bench("span record (tracing disabled)", iters, kk, |k| {
            for _ in 0..k {
                let _s = sonew::span!("bench.telemetry.probe");
            }
        });
        println!("{}", r_off.report());
        rec.add("telemetry", &r_off);
        telemetry::set_enabled(true);
        let r_on = bench("span record (tracing enabled)", iters, kk, |k| {
            for _ in 0..k {
                let _s = sonew::span!("bench.telemetry.probe");
            }
        });
        telemetry::set_enabled(false);
        let _ = telemetry::trace::drain(); // discard the probe spans
        println!("{}", r_on.report());
        rec.add("telemetry", &r_on);
        rec.derive("telemetry_span_ns_disabled".to_string(), r_off.per_iter_ns());
        rec.derive("telemetry_span_ns_enabled".to_string(), r_on.per_iter_ns());

        // end-to-end contract: an instrumented LM training step must be
        // < 5% slower with tracing enabled and unaffected when disabled
        let steps: u64 = if smoke { 6 } else { 20 };
        let time_lm = |steps: u64| -> f64 {
            let model = Transformer::new(LmConfig::small());
            let params = model.init(5);
            let blocks = sonew::optim::blocks_of(&model.layout);
            let mats = sonew::optim::mat_blocks_of(&model.layout);
            let spec = OptSpec::parse("adam").unwrap();
            let opt = spec
                .build(model.total, &blocks, &mats, &HyperParams::default())
                .unwrap();
            let provider = sonew::coordinator::trainer::BackendLmProvider::new(
                Box::new(NativeBackend::new()),
                "lm_small_grads",
                sonew::data::LmCorpus::new(model.cfg.vocab, 6),
                4,
                model.cfg.seq,
            );
            let cfg = sonew::coordinator::SessionConfig {
                train: sonew::coordinator::TrainConfig {
                    steps,
                    schedule: sonew::coordinator::Schedule::Constant { lr: 1e-3 },
                    ..Default::default()
                },
                ..Default::default()
            };
            let mut s =
                sonew::coordinator::TrainSession::new(spec, opt, params, provider, cfg)
                    .unwrap();
            let t = std::time::Instant::now();
            s.run().unwrap();
            t.elapsed().as_nanos() as f64 / 1000.0 / steps as f64
        };
        let _ = time_lm(steps); // warm the executor + backend caches
        let off_us = time_lm(steps);
        telemetry::set_enabled(true);
        let on_us = time_lm(steps);
        telemetry::set_enabled(false);
        let _ = telemetry::trace::drain();
        let pct = (on_us - off_us) / off_us * 100.0;
        println!("    lm step tracing off: {off_us:.1} us/step");
        println!("    lm step tracing on : {on_us:.1} us/step ({pct:+.1}%)");
        rec.derive("telemetry_lm_step_us_off".to_string(), off_us);
        rec.derive("telemetry_lm_step_us_on".to_string(), on_us);
        rec.derive("telemetry_lm_step_overhead_pct".to_string(), pct);
    }

    let report = rec.finish(smoke, sonew::linalg::hw_threads());
    let mut sink = JsonFileSink::from_env();
    match sink.emit(&report) {
        Ok(()) => println!("bench trajectory written to {}", sink.path.display()),
        Err(e) => eprintln!("{e:#}"),
    }
    println!("bench done");
}
