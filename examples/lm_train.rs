//! End-to-end Figure-3 driver: pretrain the transformer LM on the
//! synthetic corpus with AdaFactor vs tridiag-SONew. Hermetic on a clean
//! clone — gradients run through the native transformer
//! (`models::transformer`) and the SONew update through the native
//! tridiag kernel. With `--features xla` + `make artifacts` the same
//! driver executes the AOT HLO programs through PJRT instead (the Pallas
//! L1 kernel is inside `sonew_tridiag_lm.hlo.txt`).
//!
//!     cargo run --release --example lm_train -- --steps 200 --verbose
use sonew::cli::Args;
use sonew::tables::lm::{run, LmRunConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    run(&LmRunConfig::from_args(&args, 200, true))
}
