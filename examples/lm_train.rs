//! End-to-end Figure-3 driver: pretrain the transformer LM on the
//! synthetic corpus with AdaFactor vs tridiag-SONew, gradients AND the
//! SONew update both executing as AOT HLO programs through PJRT (the
//! Pallas L1 kernel is inside `sonew_tridiag_lm.hlo.txt`). Python never
//! runs. Requires `make artifacts`.
//!
//!     cargo run --release --example lm_train -- --steps 200 --verbose
use sonew::cli::Args;
use sonew::tables::lm::{run, LmRunConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let cfg = LmRunConfig {
        steps: args.u64_or("steps", 200),
        lr: args.f32_or("lr", 3e-3),
        log_every: args.u64_or("log-every", 5),
        verbose: !args.has("quiet"),
        sonew_via_hlo: !args.has("native-sonew"),
    };
    run(&cfg)
}
