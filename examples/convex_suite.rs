//! Table 9/10/11 driver: convex least-squares experiments — rfdSON(2/5)
//! vs tridiag-SONew test accuracy on the three synthesized datasets.
//!
//!     cargo run --release --example convex_suite -- [--scale 1.0] [--epochs 20]
use sonew::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    sonew::tables::convex::run(
        args.f32_or("scale", 1.0),
        args.usize_or("epochs", 20),
    )?;
    Ok(())
}
