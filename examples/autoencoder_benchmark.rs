//! Autoencoder benchmark driver (Tables 2/3/4/5/7/8, Figures 2/4/7).
//!
//!     cargo run --release --example autoencoder_benchmark -- [flags]
//!
//! Flags:
//!   --steps N           training steps per optimizer (default 60)
//!   --batch B           minibatch size (default 256; T4 sweeps this)
//!   --precision f32|bf16
//!   --gamma G           Algorithm-3 tolerance (Table 5's stable variant)
//!   --ablation band     run the Table 3 band-size ablation (0/1/4/10)
//!   --ablation batch    run the Table 4 batch-size ablation
//!   --ablation stable   run Table 5 (bf16 with vs without Algorithm 3)
//!   --extended          Figure 7 baselines (KFAC/Eva/FishLeg proxies)
//!   --native            force the native gradient engine
//!   --small             use the scaled-down AE
use sonew::cli::Args;
use sonew::tables::autoencoder::{run, AeBenchConfig};
use sonew::util::Precision;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let mut cfg = AeBenchConfig {
        steps: args.u64_or("steps", 60),
        batch: args.usize_or("batch", 256),
        gamma: args.f32_or("gamma", 0.0),
        full: !args.has("small"),
        force_native: args.has("native"),
        verbose: args.has("verbose"),
        seed: args.u64_or("seed", 0),
        ..Default::default()
    };
    if let Some(p) = args.get("precision").and_then(Precision::parse) {
        cfg.precision = p;
    }
    match args.get("ablation") {
        Some("band") => {
            // Table 3
            cfg.optimizers = vec![];
            cfg.band_sizes = vec![0, 1, 4, 10];
            run(&cfg, "t3_band")?;
        }
        Some("batch") => {
            // Table 4: batch sizes (paper: 100/1000/5000/10000; default
            // here keeps CPU wall time sane — pass --batches to widen)
            cfg.optimizers = ["rmsprop", "adam", "shampoo", "tridiag-sonew", "band-sonew"]
                .map(String::from)
                .to_vec();
            for b in args.list_or("batches", "100,1000") {
                cfg.batch = b.parse().unwrap_or(256);
                run(&cfg, &format!("t4_batch{b}"))?;
            }
        }
        Some("stable") => {
            // Table 5: bf16 with and without Algorithm 3
            cfg.precision = Precision::Bf16;
            cfg.optimizers = vec!["tridiag-sonew".into(), "band-sonew".into()];
            cfg.gamma = 0.0;
            run(&cfg, "t5_bf16_plain")?;
            cfg.gamma = args.f32_or("gamma", 1e-5).max(1e-8);
            run(&cfg, "t5_bf16_stable")?;
        }
        _ => {
            if args.has("extended") {
                cfg.optimizers =
                    vec!["kfac".into(), "eva".into(), "fishleg".into(), "tridiag-sonew".into()];
                run(&cfg, "f7_extended")?;
            } else {
                let tag = match cfg.precision {
                    Precision::F32 => "t2_f32",
                    Precision::Bf16 => "t8_bf16",
                };
                run(&cfg, tag)?;
            }
        }
    }
    Ok(())
}
