//! Figure 1/5/6 driver: ViT-proxy and GNN-proxy benchmarks — validation
//! quality vs steps for tridiag-SONew against Momentum / RMSProp / Adam /
//! rfdSON / Shampoo (DESIGN.md §5 documents the dataset substitutions).
//!
//!     cargo run --release --example vit_gnn_proxy -- [--steps 600] [--which vit|gnn|both]
use sonew::cli::Args;
use sonew::tables::vit_gnn::{run, Proxy};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let steps = args.u64_or("steps", 600);
    let batch = args.usize_or("batch", 64);
    match args.get_or("which", "both") {
        "vit" => {
            run(Proxy::Vit, steps, batch)?;
        }
        "gnn" => {
            run(Proxy::Gnn, steps, batch)?;
        }
        _ => {
            run(Proxy::Gnn, steps, batch)?;
            run(Proxy::Vit, steps, batch)?;
        }
    }
    Ok(())
}
