//! Quickstart: train the paper's autoencoder benchmark with tridiag-SONew
//! (Algorithm 1) in ~20 lines of library use. Uses the native gradient
//! engine so it runs with or without AOT artifacts.
//!
//!     cargo run --release --example quickstart

use sonew::coordinator::trainer::NativeAeProvider;
use sonew::coordinator::{Schedule, TrainConfig, TrainSession};
use sonew::data::SynthImages;
use sonew::models::Mlp;
use sonew::optim::{HyperParams, OptSpec};

fn main() -> anyhow::Result<()> {
    // the scaled-down autoencoder (full 2.84M-param model: Mlp::autoencoder())
    let mlp = Mlp::autoencoder_small();
    let mut rng = sonew::util::Rng::new(0);
    let params = mlp.init(&mut rng);

    // tridiag-SONew with Adam grafting, exactly the paper's §5 setup —
    // the spec string is the same one the CLI and sweeps consume
    let hp = HyperParams { beta2: 0.95, eps: 1e-6, ..Default::default() };
    let mut opt = OptSpec::parse("tridiag-sonew:gamma=1e-8,graft=adam")?
        .build(mlp.total, &mlp.blocks(), &mlp.mat_blocks(), &hp)?;

    let cfg = TrainConfig {
        steps: 100,
        schedule: Schedule::CosineWarmup { lr: 8.6e-3, warmup: 5, total: 100, final_frac: 0.1 },
        log_every: 10,
        verbose: true,
        ..Default::default()
    };
    let provider = NativeAeProvider::new(mlp.clone(), SynthImages::new(1), 64);
    // the one training engine (Execution API v1): every run — CLI,
    // tables, sweeps — is a TrainSession; this one is ephemeral (no
    // checkpointing), the serving shape adds --checkpoint/--resume
    let (_params, metrics) =
        TrainSession::ephemeral(&mut opt, params, provider, cfg.clone()).finish()?;
    println!(
        "quickstart done: loss {:.3} -> {:.3} in {:.1}s ({} per step opt time {:?})",
        metrics.points.first().unwrap().loss,
        metrics.tail_mean_loss(5).unwrap(),
        metrics.total_wall().as_secs_f64(),
        opt.name(),
        metrics.opt_time / cfg.steps as u32,
    );
    Ok(())
}
